//! Perf microbenches: the hot paths behind every experiment —
//! blocked GEMM (with plan sweep), the parallel threads × size axis
//! (emits `BENCH_gemm.json` for the perf trajectory), the fused rank-1
//! product, sparse SpMM, Householder QR, Jacobi SVD, the artifact
//! engine's end-to-end execute, and a disarmed fail-point overhead
//! guard (<1% of a block read, asserted). Drives the EXPERIMENTS.md
//! §Perf log.
//!
//! Run: `cargo bench --bench perf_micro`.
//! Env: `SRSVD_BENCH_QUICK=1` (CI smoke), `SRSVD_BENCH_JSON=<path>`
//! (default `BENCH_gemm.json`).

use std::sync::Arc;

use srsvd::bench::{Bencher, Table};
use srsvd::linalg::gemm::kernels::{active_simd, with_precision, with_simd};
use srsvd::linalg::gemm::{Precision, Simd};
use srsvd::linalg::{
    gemm, householder_qr, jacobi_svd, matmul, Csr, Dense, JacobiOpts, MatmulPlan,
};
use srsvd::parallel::ThreadPool;
use srsvd::rng::{Rng, Xoshiro256pp};
use srsvd::util::json::Json;
use srsvd::util::timer::fmt_duration;

fn gflops(flops: f64, secs: f64) -> String {
    format!("{:.2}", flops / secs / 1e9)
}

fn bits_equal(a: &Dense, b: &Dense) -> bool {
    a.data()
        .iter()
        .zip(b.data())
        .all(|(x, y)| x.to_bits() == y.to_bits())
}

/// The parallel-execution axis: simd × precision × threads × size for
/// `matmul` and the fused `matmul_rank1`, pinned to explicit pools.
/// Verifies on the fly that every kernel tier is bitwise invariant to
/// thread count, and that the Exact tier is one bit-equality class
/// across SIMD modes; emits the JSON rows that seed the bench
/// trajectory (uploaded as a CI artifact). The `speedup_vs_scalar_1t`
/// column at `n=1024 t=1` is the acceptance number for the AVX2/FMA
/// microkernels.
fn parallel_axis(b: &Bencher, quick: bool) -> Json {
    let sizes: &[usize] = if quick { &[512, 1024] } else { &[256, 512, 1024] };
    let threads: &[usize] = &[1, 2, 4, 8];
    // Scalar/Fast is omitted: the Fast packed path only differs from
    // Exact under FMA, so it would re-measure Scalar/Exact.
    let combos: &[(Simd, Precision)] = &[
        (Simd::Scalar, Precision::Exact),
        (Simd::Avx2, Precision::Exact),
        (Simd::Avx2, Precision::Fast),
    ];
    let mut rows: Vec<Json> = Vec::new();

    println!(
        "== parallel GEMM: simd x precision x threads x size (f64, square; detected simd: {}) ==",
        active_simd().name()
    );
    let mut t = Table::new(&[
        "op", "n", "simd", "tier", "threads", "time", "GFLOP/s", "speedup", "vs scalar",
    ]);
    for &n in sizes {
        let mut rng = Xoshiro256pp::seed_from_u64(n as u64);
        let a = Dense::gaussian(n, n, &mut rng);
        let c = Dense::gaussian(n, n, &mut rng);
        let u: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let v: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let flops = 2.0 * (n as f64).powi(3);
        for op in ["matmul", "matmul_rank1"] {
            let run_once = |simd: Simd, prec: Precision, pool: &ThreadPool| -> Dense {
                with_simd(simd, || {
                    with_precision(prec, || match op {
                        "matmul" => {
                            gemm::matmul_with_plan_pool(&a, &c, MatmulPlan::default(), pool)
                        }
                        _ => gemm::matmul_rank1_with_plan_pool(
                            &a,
                            &c,
                            &u,
                            &v,
                            MatmulPlan::default(),
                            pool,
                        ),
                    })
                })
            };
            let p1 = ThreadPool::new(1);
            let scalar_ref = run_once(Simd::Scalar, Precision::Exact, &p1);
            let mut scalar_1t_mean = 0.0;
            for &(simd, prec) in combos {
                let reference = run_once(simd, prec, &p1);
                // The Exact tier is one bit-equality class across SIMD
                // modes — that's its contract.
                if prec == Precision::Exact {
                    assert!(
                        bits_equal(&scalar_ref, &reference),
                        "{op} n={n} simd={}: exact tier diverged from scalar!",
                        simd.name()
                    );
                }
                let mut base_mean = 0.0;
                for &nt in threads {
                    let pool = Arc::new(ThreadPool::new(nt));
                    let label =
                        format!("{op} n={n} {}/{} t={nt}", simd.name(), prec.name());
                    let stats = b.run(&label, || run_once(simd, prec, &pool));
                    if nt == 1 {
                        base_mean = stats.mean_s;
                        if simd == Simd::Scalar && prec == Precision::Exact {
                            scalar_1t_mean = stats.mean_s;
                        }
                    }
                    let speedup = base_mean / stats.mean_s.max(1e-12);
                    let vs_scalar = scalar_1t_mean / stats.mean_s.max(1e-12);
                    // Thread-count invariance is part of the contract —
                    // for every tier (Fast is deterministic too, its
                    // rounding just differs from scalar).
                    let check = run_once(simd, prec, &pool);
                    let bit_identical = bits_equal(&reference, &check);
                    assert!(bit_identical, "{label}: thread-count variance!");
                    t.row(&[
                        op.to_string(),
                        n.to_string(),
                        simd.name().to_string(),
                        prec.name().to_string(),
                        nt.to_string(),
                        fmt_duration(stats.mean_s),
                        gflops(flops, stats.mean_s),
                        format!("{speedup:.2}x"),
                        format!("{vs_scalar:.2}x"),
                    ]);
                    rows.push(Json::obj(vec![
                        ("op", Json::str(op)),
                        ("n", Json::num(n as f64)),
                        ("simd", Json::str(simd.name())),
                        ("precision", Json::str(prec.name())),
                        ("threads", Json::num(nt as f64)),
                        ("mean_s", Json::num(stats.mean_s)),
                        ("p95_s", Json::num(stats.p95_s)),
                        ("gflops", Json::num(flops / stats.mean_s / 1e9)),
                        ("speedup_vs_1", Json::num(speedup)),
                        ("speedup_vs_scalar_1t", Json::num(vs_scalar)),
                        ("bit_identical", Json::Bool(bit_identical)),
                    ]));
                }
            }
        }
    }
    print!("{}", t.render());

    Json::obj(vec![
        ("bench", Json::str("gemm_parallel")),
        ("quick", Json::Bool(quick)),
        ("detected_simd", Json::str(active_simd().name())),
        (
            "host_parallelism",
            Json::num(
                std::thread::available_parallelism()
                    .map(|p| p.get())
                    .unwrap_or(1) as f64,
            ),
        ),
        ("cases", Json::Arr(rows)),
    ])
}

/// Time a disarmed fail-point evaluation and enforce the registry's
/// "invisible when off" contract: one site check must stay under 1% of
/// even the cheapest instrumented operation (a 10µs block read is the
/// conservative floor — real reads and sweeps are far larger). Returns
/// the per-check cost in nanoseconds for the JSON trajectory.
fn disarmed_fault_overhead_ns() -> f64 {
    srsvd::util::faults::disarm();
    let iters = 5_000_000u64;
    let t0 = std::time::Instant::now();
    for i in 0..iters {
        // Branch on the result so the loop cannot be elided.
        if srsvd::util::faults::check("stream.read").is_err() {
            panic!("disarmed check reported a fault at iter {i}");
        }
    }
    let per_check_s = t0.elapsed().as_secs_f64() / iters as f64;
    let share = per_check_s / 10e-6;
    println!(
        "\n== disarmed fail-point overhead ==\n  {:.2}ns per check ({:.4}% of a 10µs block read)",
        per_check_s * 1e9,
        share * 100.0
    );
    assert!(
        share < 0.01,
        "disarmed fail-point costs {:.2}ns per check — over 1% of a 10µs block read",
        per_check_s * 1e9
    );
    per_check_s * 1e9
}

fn main() {
    let b = Bencher::from_env();
    let quick = std::env::var("SRSVD_BENCH_QUICK").as_deref() == Ok("1");
    let mut rng = Xoshiro256pp::seed_from_u64(0);

    // Threads × size axis first: it feeds the committed JSON trajectory.
    let mut report = parallel_axis(&b, quick);
    let fault_ns = disarmed_fault_overhead_ns();
    if let Json::Obj(pairs) = &mut report {
        pairs.push(("disarmed_fault_check_ns".to_string(), Json::num(fault_ns)));
    }
    let json_path = std::env::var("SRSVD_BENCH_JSON").unwrap_or_else(|_| "BENCH_gemm.json".into());
    match std::fs::write(&json_path, report.to_string_pretty()) {
        Ok(()) => println!("\nwrote {json_path}"),
        Err(e) => eprintln!("\ncould not write {json_path}: {e}"),
    }
    println!();

    println!("== GEMM plan sweep (512x512x512 f64) ==");
    let a = Dense::gaussian(512, 512, &mut rng);
    let c = Dense::gaussian(512, 512, &mut rng);
    let flops = 2.0 * 512f64.powi(3);
    let mut t = Table::new(&["mc", "kc", "time", "GFLOP/s"]);
    for (mc, kc) in [(16, 64), (32, 128), (64, 256), (128, 256), (64, 512), (256, 256)] {
        let s = b.run(&format!("gemm {mc}/{kc}"), || {
            gemm::matmul_with_plan(&a, &c, MatmulPlan { mc, kc })
        });
        t.row(&[
            mc.to_string(),
            kc.to_string(),
            fmt_duration(s.mean_s),
            gflops(flops, s.mean_s),
        ]);
    }
    print!("{}", t.render());

    println!("\n== fused rank-1 vs matmul+subtract (200x2000 · 2000x40) ==");
    let x = Dense::gaussian(200, 2000, &mut rng);
    let om = Dense::gaussian(2000, 40, &mut rng);
    let u: Vec<f64> = (0..200).map(|_| rng.next_gaussian()).collect();
    let v: Vec<f64> = (0..40).map(|_| rng.next_gaussian()).collect();
    let s1 = b.run("fused", || gemm::matmul_rank1(&x, &om, &u, &v));
    let s2 = b.run("unfused", || {
        let mut c = matmul(&x, &om);
        for i in 0..200 {
            for j in 0..40 {
                c[(i, j)] -= u[i] * v[j];
            }
        }
        c
    });
    println!(
        "  fused {}  unfused {}  ({:+.1}%)",
        fmt_duration(s1.mean_s),
        fmt_duration(s2.mean_s),
        (s1.mean_s / s2.mean_s - 1.0) * 100.0
    );

    println!("\n== sparse SpMM (2000x20000, densities) x 20 ==");
    let mut t = Table::new(&["density", "nnz", "time", "GFLOP/s(nnz)"]);
    for density in [0.001, 0.01, 0.05] {
        let sp = Csr::random(2000, 20000, density, &mut rng, |r| r.next_uniform());
        let bmat = Dense::gaussian(20000, 20, &mut rng);
        let s = b.run(&format!("spmm d={density}"), || sp.matmul_dense(&bmat));
        t.row(&[
            density.to_string(),
            sp.nnz().to_string(),
            fmt_duration(s.mean_s),
            gflops(2.0 * sp.nnz() as f64 * 20.0, s.mean_s),
        ]);
    }
    print!("{}", t.render());

    println!("\n== Householder QR (m x 20) ==");
    let mut t = Table::new(&["m", "time"]);
    for m in [500usize, 2000, 8000] {
        let a = Dense::gaussian(m, 20, &mut rng);
        let s = b.run(&format!("qr {m}"), || householder_qr(&a));
        t.row(&[m.to_string(), fmt_duration(s.mean_s)]);
    }
    print!("{}", t.render());

    println!("\n== one-sided Jacobi SVD (n x K) ==");
    let mut t = Table::new(&["n", "K", "time"]);
    for (n, k) in [(1000usize, 20usize), (4000, 20), (1000, 64)] {
        let w = Dense::gaussian(n, k, &mut rng);
        let s = b.run(&format!("jacobi {n}x{k}"), || {
            jacobi_svd(&w, JacobiOpts::default())
        });
        t.row(&[n.to_string(), k.to_string(), fmt_duration(s.mean_s)]);
    }
    print!("{}", t.render());

    // Artifact engine end-to-end (compile once, execute many). Needs
    // the `pjrt` feature: the default build's stub Executor can't run.
    let dir = std::path::Path::new("artifacts");
    if cfg!(feature = "pjrt") && dir.join("manifest.json").exists() {
        println!("\n== artifact engine: srsvd_scored 100x1000 k=10 q=0 ==");
        let mut ex = srsvd::runtime::Executor::new(dir).unwrap();
        let spec = ex.manifest().find_srsvd(100, 1000, 10, 0).unwrap().clone();
        let compile_s = ex.ensure_compiled(&spec.name).unwrap();
        let x = Dense::from_fn(100, 1000, |_, _| rng.next_uniform());
        let mu = x.row_means();
        let omega = Dense::gaussian(1000, spec.kk, &mut rng);
        let s = b.run("artifact execute", || {
            ex.run_srsvd(&spec, &x, &mu, &omega).unwrap()
        });
        println!(
            "  compile(once)={}  execute mean={} p95={}",
            fmt_duration(compile_s),
            fmt_duration(s.mean_s),
            fmt_duration(s.p95_s)
        );
        // Native comparison point.
        let cfg = srsvd::svd::SvdConfig::paper(10);
        let sn = b.run("native same config", || {
            let mut r = Xoshiro256pp::seed_from_u64(3);
            srsvd::svd::ShiftedRsvd::new(cfg)
                .factorize(&x, &mu, &mut r)
                .unwrap()
        });
        println!("  native engine same config: {}", fmt_duration(sn.mean_s));
    } else {
        println!("\n(artifacts not built; skipping artifact-engine bench)");
    }
}
