//! Bench: the §4 efficiency claim — S-RSVD on sparse X vs RSVD on the
//! densified X̄, sweeping n. The paper argues O(nnz·k + (m+n)k²) vs
//! O(mnk); the speedup should grow with n at fixed nnz/n.
//!
//! Run: `cargo bench --bench efficiency` (SRSVD_FULL=1 for the big sweep).

use srsvd::experiments::efficiency;

fn main() {
    let quick = srsvd::experiments::quick_mode();
    let full = std::env::var("SRSVD_FULL").as_deref() == Ok("1");
    let points: Vec<(usize, f64)> = if quick {
        vec![(2000, 0.01), (8000, 0.005)]
    } else if full {
        vec![
            (2000, 0.01),
            (8000, 0.005),
            (20_000, 0.002),
            (50_000, 0.001),
            (100_000, 0.0005),
        ]
    } else {
        vec![(2000, 0.01), (8000, 0.005), (20_000, 0.002)]
    };

    println!("== §4 efficiency: sparse S-RSVD vs densified RSVD (m=500, k=10) ==");
    let rows = efficiency::sweep(500, &points, 10, 42);
    print!("{}", efficiency::render(&rows));

    let last = rows.last().unwrap();
    println!(
        "\nheadline: at n={} the densified baseline pays {:.1}x the wall-clock\n\
         (and materializes {} dense f64s the sparse path never allocates).",
        last.n,
        last.speedup(),
        last.densified_elems
    );
    println!("paper (§4): S-RSVD is strictly more efficient whenever X is sparse and mu != 0.");
}
