//! Bench: regenerate Figure 1b — MSE-SUM (k = 1..100) vs sample size n
//! for 100×n uniform matrices.
//!
//! Run: `cargo bench --bench fig1b`.

use srsvd::bench::Table;
use srsvd::experiments::{fig1, k_grid, quick_mode};

fn main() {
    let quick = quick_mode();
    let ks = k_grid(100, true); // MSE-SUM grid is always thinned for benches
    let ns: Vec<usize> = if quick {
        vec![200, 1000, 5000]
    } else {
        vec![100, 200, 500, 1000, 2000, 5000, 10000]
    };
    println!("== Fig 1b: MSE-SUM vs sample size (100xn uniform) ==");
    let mut t = Table::new(&["n", "S-RSVD", "RSVD", "RSVD/S-RSVD"]);
    for (n, s, r) in fig1::fig1b(&ns, &ks, 42) {
        t.row(&[
            n.to_string(),
            format!("{s:.3}"),
            format!("{r:.3}"),
            format!("{:.3}", r / s.max(1e-300)),
        ]);
    }
    print!("{}", t.render());
    println!("\npaper: S-RSVD more accurate and more stable across sample sizes.");
}
