//! Job-lifecycle integration tests: cancellation, TTL eviction, and
//! the content-addressed result cache, over a real loopback server.
//!
//! Pinned contracts:
//! - `DELETE /v1/jobs/{id}`: `200` on a pending/running job, whose
//!   claiming `GET` then surfaces `Error::Cancelled` as `410 Gone`;
//!   `404` on an unknown id; `409` once the result was delivered.
//! - Parked entries expire after `result_ttl_s`. Time flows through
//!   the injectable `Clock`, so eviction is driven by a hand-advanced
//!   fake — no test sleeps.
//! - A repeated waited submit replays the cold run's exact bytes from
//!   the result cache without touching the coordinator (`native_jobs`
//!   stays flat while `cache_hits` ticks).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use srsvd::coordinator::{Coordinator, CoordinatorConfig, EnginePreference};
use srsvd::data::Distribution;
use srsvd::linalg::stream::StreamConfig;
use srsvd::server::client::SubmitOutcome;
use srsvd::server::protocol::{generator_input, JobRequest};
use srsvd::server::{Client, Clock, Server, ServerConfig};
use srsvd::util::json::Json;

fn coordinator(native_workers: usize) -> Arc<Coordinator> {
    Arc::new(
        Coordinator::start(CoordinatorConfig {
            native_workers,
            queue_capacity: 16,
            artifact_dir: None,
            pool_threads: Some(2),
            io_threads: None,
            ..Default::default()
        })
        .unwrap(),
    )
}

fn server_config() -> ServerConfig {
    ServerConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        ..Default::default()
    }
}

fn client_for(server: &Server) -> Client {
    Client::connect(&server.local_addr().to_string()).unwrap()
}

fn counter(client: &mut Client, key: &str) -> u64 {
    client.metrics().unwrap().get(key).unwrap().as_u64().unwrap()
}

/// A job slow enough that follow-up requests on the same loopback
/// connection land while it still occupies the single native worker
/// (same shape the `server.rs` suite uses as its "slow job").
fn blocker_request() -> JobRequest {
    let mut req = JobRequest::new(
        generator_input(300, 500, Distribution::Uniform, 4, None, None),
        16,
    );
    req.config = req.config.with_fixed_power(2);
    req.engine = EnginePreference::Native;
    req
}

/// A small job that queues behind the blocker.
fn victim_request(seed: u64) -> JobRequest {
    let mut req = JobRequest::new(generator_input(8, 24, Distribution::Uniform, seed, None, None), 2);
    req.engine = EnginePreference::Native;
    req
}

#[test]
fn cancel_unknown_id_is_404_and_malformed_id_is_400() {
    let coord = coordinator(1);
    let server = Server::bind(Arc::clone(&coord), &server_config(), StreamConfig::default())
        .unwrap();
    let mut client = client_for(&server);

    let err = client.cancel(123_456).unwrap_err();
    let text = format!("{err}");
    assert!(text.contains("404"), "unknown id must be 404, got: {text}");

    let (status, _) = client.request("DELETE", "/v1/jobs/not-a-number", None).unwrap();
    assert_eq!(status, 400, "malformed id must be 400");

    server.shutdown();
}

#[test]
fn cancelled_pending_job_surfaces_as_410_gone_then_409_on_recancel() {
    let coord = coordinator(1);
    let server = Server::bind(Arc::clone(&coord), &server_config(), StreamConfig::default())
        .unwrap();
    let mut client = client_for(&server);

    // Occupy the only native worker so the victim stays queued (and its
    // pre-execution cancel checkpoint is guaranteed to see the flag).
    let SubmitOutcome::Queued(_blocker) = client.submit(&blocker_request()).unwrap() else {
        panic!("wait=false submit must queue");
    };
    let SubmitOutcome::Queued(victim) = client.submit(&victim_request(7)).unwrap() else {
        panic!("wait=false submit must queue");
    };

    assert!(client.cancel(victim).unwrap(), "cancel of a pending job must answer 200");
    assert!(counter(&mut client, "cancelled") >= 1, "cancelled counter must tick");

    // The claiming GET observes the cooperative failure as 410 Gone
    // with the Error::Cancelled text in the job result body.
    let err = client.wait(victim).unwrap_err();
    let text = format!("{err}");
    assert!(text.contains("410"), "cancelled result must claim as 410, got: {text}");
    assert!(text.contains("cancelled"), "410 body must carry the cancel reason, got: {text}");

    // The 410 delivery is a delivery: a late re-cancel answers 409.
    assert!(!client.cancel(victim).unwrap(), "re-cancel after delivery must answer 409");

    server.shutdown();
}

/// Hand-advanced [`Clock`]: `now_ms` is whatever the test last stored.
struct FakeClock(AtomicU64);

impl Clock for FakeClock {
    fn now_ms(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[test]
fn ttl_eviction_under_the_fake_clock_never_sleeps() {
    let coord = coordinator(1);
    let clock = Arc::new(FakeClock(AtomicU64::new(0)));
    let config = ServerConfig { result_ttl_s: 5, ..server_config() };
    let server = Server::bind_with_clock(
        Arc::clone(&coord),
        &config,
        StreamConfig::default(),
        Arc::clone(&clock) as Arc<dyn Clock>,
    )
    .unwrap();
    let mut client = client_for(&server);

    let SubmitOutcome::Queued(_blocker) = client.submit(&blocker_request()).unwrap() else {
        panic!("wait=false submit must queue");
    };
    let SubmitOutcome::Queued(victim) = client.submit(&victim_request(11)).unwrap() else {
        panic!("wait=false submit must queue");
    };

    // Zero-timeout poll: still queued behind the blocker, so the server
    // answers 202 and re-parks the handle under a fresh TTL deadline.
    match client.wait_timeout(victim, 0.0).unwrap() {
        srsvd::server::client::WaitOutcome::Running => {}
        other => panic!("victim must still be running, got {other:?}"),
    }
    assert_eq!(counter(&mut client, "evicted"), 0, "nothing may expire at t=0");

    // Advance past the 5 s TTL; the next routed request runs the reaper.
    clock.0.store(5_001, Ordering::Relaxed);
    assert!(counter(&mut client, "evicted") >= 1, "expired parked entries must be evicted");

    let err = client.wait(victim).unwrap_err();
    let text = format!("{err}");
    assert!(text.contains("404"), "an evicted id must be gone (404), got: {text}");

    server.shutdown();
}

#[test]
fn cache_hit_replays_cold_bytes_and_skips_the_coordinator() {
    let coord = coordinator(2);
    let server = Server::bind(Arc::clone(&coord), &server_config(), StreamConfig::default())
        .unwrap();
    let mut client = client_for(&server);

    let mut req = JobRequest::new(
        generator_input(40, 120, Distribution::Uniform, 9, None, None),
        6,
    );
    req.engine = EnginePreference::Native;
    req.seed = 3;
    req.wait = true;
    let body = req.to_json();

    let (status, cold) = client.request("POST", "/v1/jobs", Some(&body)).unwrap();
    assert_eq!(status, 200, "cold waited submit must answer with the result");
    assert_eq!(cold.get("ok").unwrap(), &Json::Bool(true));
    assert!(counter(&mut client, "cache_misses") >= 1, "cold run must count a miss");
    let native_after_cold = counter(&mut client, "native_jobs");

    let (status, warm) = client.request("POST", "/v1/jobs", Some(&body)).unwrap();
    assert_eq!(status, 200, "warm waited submit must answer with the result");
    assert_eq!(warm, cold, "cache hit must replay the cold run byte-for-byte");

    assert!(counter(&mut client, "cache_hits") >= 1, "warm run must count a hit");
    assert!(counter(&mut client, "cache_bytes") > 0, "cached bodies must be accounted");
    assert_eq!(
        counter(&mut client, "native_jobs"),
        native_after_cold,
        "a cache hit must bypass the coordinator entirely"
    );

    server.shutdown();
}
