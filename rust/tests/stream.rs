//! Out-of-core parity: a `Streamed` factorization must be
//! **byte-identical** to the in-memory `Dense` path — for every block
//! size, every thread-pool size (1/2/8), and every source kind — plus a
//! file-source round-trip (write header+blocks, read back, factorize)
//! and the coordinator end-to-end.

use std::sync::Arc;

use srsvd::coordinator::{
    Coordinator, CoordinatorConfig, EnginePreference, JobSpec, MatrixInput, ShiftSpec,
};
use srsvd::data::Distribution;
use srsvd::linalg::stream::{
    spill_to_file, FileSource, GeneratorSource, InMemorySource, MatrixSource, StreamConfig,
    Streamed,
};
use srsvd::linalg::Dense;
use srsvd::parallel::{with_pool, ThreadPool};
use srsvd::rng::Xoshiro256pp;
use srsvd::svd::{Factorization, ShiftedRsvd, SvdConfig};

fn dense_bits(x: &Dense) -> Vec<u64> {
    x.data().iter().map(|v| v.to_bits()).collect()
}

fn assert_identical(a: &Factorization, b: &Factorization, what: &str) {
    assert_eq!(dense_bits(&a.u), dense_bits(&b.u), "{what}: u bytes differ");
    assert_eq!(
        a.s.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        b.s.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "{what}: s bytes differ"
    );
    assert_eq!(dense_bits(&a.v), dense_bits(&b.v), "{what}: v bytes differ");
}

/// Big enough that the sampling product clears the parallel threshold
/// (150·900·24 ≈ 3.2M flops), matching tests/determinism.rs.
fn input_matrix() -> Dense {
    let mut rng = Xoshiro256pp::seed_from_u64(0x57E4);
    Dense::from_fn(150, 900, |_, _| rng.next_uniform())
}

fn cfg() -> SvdConfig {
    SvdConfig { k: 12, oversample: 12, power_iters: 1, ..Default::default() }
}

fn factorize(x: &dyn srsvd::svd::MatVecOps, seed: u64) -> Factorization {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    ShiftedRsvd::new(cfg())
        .factorize_mean_centered(x, &mut rng)
        .expect("factorize")
}

#[test]
fn streamed_matches_dense_across_block_sizes_and_pools_1_2_8() {
    let x = input_matrix();
    for threads in [1usize, 2, 8] {
        let pool = Arc::new(ThreadPool::new(threads));
        with_pool(&pool, || {
            let base = factorize(&x, 42);
            for block_rows in [1usize, 7, 64, 150] {
                let s = Streamed::with_block_rows(InMemorySource::new(x.clone()), block_rows);
                let got = factorize(&s, 42);
                assert_identical(
                    &base,
                    &got,
                    &format!("streamed bl={block_rows}, pool={threads}"),
                );
            }
        });
    }
}

#[test]
fn streamed_pool_sizes_agree_with_each_other() {
    // The streamed path itself must be pool-size invariant (not just
    // equal to dense within one pool).
    let x = input_matrix();
    let run = |threads: usize| {
        let pool = Arc::new(ThreadPool::new(threads));
        with_pool(&pool, || {
            let s = Streamed::with_block_rows(InMemorySource::new(x.clone()), 33);
            factorize(&s, 43)
        })
    };
    let base = run(1);
    for threads in [2, 8] {
        assert_identical(&base, &run(threads), &format!("pool {threads}"));
    }
}

#[test]
fn file_source_round_trip_and_factorization() {
    let x = input_matrix();
    let path = std::env::temp_dir().join("srsvd_test_stream_roundtrip.bin");
    let file = srsvd::linalg::stream::write_matrix(&path, &x).expect("write");
    // Bytes survive the disk round trip exactly.
    assert_eq!(dense_bits(&file.materialize().expect("read")), dense_bits(&x));
    // And so does the factorization, at an awkward block size.
    let base = factorize(&x, 44);
    let got = factorize(&Streamed::with_block_rows(file, 41), 44);
    assert_identical(&base, &got, "file-source factorization");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn generator_spill_and_stream_agree() {
    // Generator → direct streaming and generator → spill-to-disk →
    // streaming must produce identical factors.
    let gen = GeneratorSource::new(140, 700, Distribution::Normal, 9).expect("gen");
    let path = std::env::temp_dir().join("srsvd_test_stream_spill.bin");
    let file: FileSource = spill_to_file(&gen, &path, 37).expect("spill");
    let direct = factorize(&Streamed::with_block_rows(gen, 53), 45);
    let spilled = factorize(&Streamed::with_block_rows(file, 29), 45);
    assert_identical(&direct, &spilled, "generator vs spilled file");
    // Both equal the fully materialized dense path.
    let dense = gen.materialize().expect("materialize");
    let base = factorize(&dense, 45);
    assert_identical(&base, &direct, "generator vs dense");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn coordinator_streamed_job_matches_dense_job() {
    let x = input_matrix();
    let run = |input: MatrixInput, pool_threads: usize| {
        let coord = Coordinator::start(CoordinatorConfig {
            native_workers: 2,
            queue_capacity: 8,
            artifact_dir: None,
            pool_threads: Some(pool_threads),
        })
        .expect("coordinator");
        let r = coord
            .submit_blocking(JobSpec {
                input,
                config: cfg(),
                shift: ShiftSpec::MeanCenter,
                engine: EnginePreference::Auto,
                seed: 99,
                score: true,
            })
            .expect("submit");
        let out = r.outcome.expect("job");
        coord.shutdown();
        out
    };
    let stream_cfg = StreamConfig { block_rows: 48, budget_mb: 64 };
    let dense_out = run(MatrixInput::Dense(x.clone()), 2);
    for pool_threads in [1usize, 2, 8] {
        let streamed_out = run(
            MatrixInput::streamed(InMemorySource::new(x.clone()), &stream_cfg),
            pool_threads,
        );
        assert_identical(
            &dense_out.factorization,
            &streamed_out.factorization,
            &format!("coordinator streamed vs dense, pool {pool_threads}"),
        );
        // The streamed scorer must agree with the dense scorer tightly
        // (different expansion of the same quantity).
        let (md, ms) = (dense_out.mse.unwrap(), streamed_out.mse.unwrap());
        assert!(
            (md - ms).abs() < 1e-8 * md.max(1.0),
            "mse dense {md} vs streamed {ms}"
        );
    }
}

/// A source that starts failing after a given row — simulates a backing
/// file truncated mid-sweep.
#[derive(Debug)]
struct FlakySource {
    inner: InMemorySource,
    fail_after_row: usize,
}

impl MatrixSource for FlakySource {
    fn shape(&self) -> (usize, usize) {
        self.inner.shape()
    }

    fn read_rows(&self, row0: usize, nrows: usize, out: &mut [f64]) -> srsvd::util::Result<()> {
        if row0 + nrows > self.fail_after_row {
            return Err(srsvd::util::Error::Io(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "simulated mid-sweep IO failure",
            )));
        }
        self.inner.read_rows(row0, nrows, out)
    }
}

#[test]
fn failing_streamed_source_fails_the_job_not_the_worker() {
    let x = input_matrix();
    let coord = Coordinator::start(CoordinatorConfig {
        native_workers: 1,
        queue_capacity: 8,
        artifact_dir: None,
        pool_threads: Some(2),
    })
    .expect("coordinator");
    let bad = FlakySource { inner: InMemorySource::new(x.clone()), fail_after_row: 60 };
    let job = |input| JobSpec {
        input,
        config: cfg(),
        shift: ShiftSpec::MeanCenter,
        engine: EnginePreference::Auto,
        seed: 1,
        score: false,
    };
    let r = coord
        .submit_blocking(job(MatrixInput::streamed(
            bad,
            &StreamConfig { block_rows: 48, budget_mb: 64 },
        )))
        .expect("submit");
    let err = r.outcome.expect_err("mid-sweep IO failure must fail the job");
    assert!(format!("{err}").contains("panicked"), "{err}");
    // The (single) worker must survive and take the next job.
    let ok = coord
        .submit_blocking(job(MatrixInput::Dense(x)))
        .expect("submit after failure");
    assert!(ok.outcome.is_ok(), "worker must outlive a failing job");
    assert_eq!(coord.metrics().failed, 1);
    coord.shutdown();
}

#[test]
fn budget_derived_blocks_change_nothing() {
    let x = input_matrix();
    let base = factorize(&x, 46);
    // 1 MiB budget on 900 columns → 145 rows/block; 64 MiB → whole matrix.
    for budget_mb in [1usize, 64] {
        let scfg = StreamConfig { block_rows: 0, budget_mb };
        let s = Streamed::new(InMemorySource::new(x.clone()), &scfg);
        assert!(s.block_rows() >= 1 && s.block_rows() <= 150);
        let got = factorize(&s, 46);
        assert_identical(&base, &got, &format!("budget {budget_mb} MiB"));
    }
}
