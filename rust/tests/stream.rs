//! Out-of-core parity: a `Streamed` factorization under
//! `PassPolicy::Exact` must be **byte-identical** to the in-memory
//! `Dense` path — for every block size, every thread-pool size (1/2/8),
//! with prefetch on and off, and every source kind — plus a file-source
//! round-trip (write header+blocks, read back, factorize) and the
//! coordinator end-to-end. `PassPolicy::Fused` trades byte-identity for
//! the pass budget: this suite pins its `≤ q + 2` source-pass count
//! (vs `2 + 2q` Exact, asserted on the `SourceStats` counters) and its
//! accuracy (≤ 1.15× the optimal rank-k residual) on every source kind.

use std::sync::Arc;

use srsvd::coordinator::{
    Coordinator, CoordinatorConfig, EnginePreference, JobSpec, MatrixInput, ShiftSpec,
};
use srsvd::data::Distribution;
use srsvd::linalg::stream::{
    spill_to_file, CsrRowSource, FileSource, GeneratorSource, InMemorySource, MatrixSource,
    StreamConfig, Streamed,
};
use srsvd::linalg::{fro_diff, Csr, Dense};
use srsvd::parallel::{with_pool, ThreadPool};
use srsvd::rng::{Rng, Xoshiro256pp};
use srsvd::svd::deterministic::optimal_residual;
use srsvd::svd::{Factorization, MatVecOps, PassPolicy, ShiftedRsvd, StopCriterion, SvdConfig};

fn dense_bits(x: &Dense) -> Vec<u64> {
    x.data().iter().map(|v| v.to_bits()).collect()
}

fn assert_identical(a: &Factorization, b: &Factorization, what: &str) {
    assert_eq!(dense_bits(&a.u), dense_bits(&b.u), "{what}: u bytes differ");
    assert_eq!(
        a.s.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        b.s.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "{what}: s bytes differ"
    );
    assert_eq!(dense_bits(&a.v), dense_bits(&b.v), "{what}: v bytes differ");
}

/// Big enough that the sampling product clears the parallel threshold
/// (150·900·24 ≈ 3.2M flops), matching tests/determinism.rs.
fn input_matrix() -> Dense {
    let mut rng = Xoshiro256pp::seed_from_u64(0x57E4);
    Dense::from_fn(150, 900, |_, _| rng.next_uniform())
}

fn cfg() -> SvdConfig {
    SvdConfig::paper(12).with_fixed_power(1)
}

fn factorize(x: &dyn srsvd::svd::MatVecOps, seed: u64) -> Factorization {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    ShiftedRsvd::new(cfg())
        .factorize_mean_centered(x, &mut rng)
        .expect("factorize")
}

#[test]
fn streamed_matches_dense_across_block_sizes_and_pools_1_2_8() {
    // Prefetch on (the default) and off are both byte-identical to the
    // dense path: the pipeline only moves reads off-thread, never the
    // accumulation order.
    let x = input_matrix();
    for threads in [1usize, 2, 8] {
        let pool = Arc::new(ThreadPool::new(threads));
        with_pool(&pool, || {
            let base = factorize(&x, 42);
            for block_rows in [1usize, 7, 64, 150] {
                for prefetch in [true, false] {
                    let s = Streamed::with_block_rows(InMemorySource::new(x.clone()), block_rows)
                        .with_prefetch(prefetch);
                    let got = factorize(&s, 42);
                    assert_identical(
                        &base,
                        &got,
                        &format!("streamed bl={block_rows}, pool={threads}, prefetch={prefetch}"),
                    );
                }
            }
        });
    }
}

#[test]
fn streamed_pool_sizes_agree_with_each_other() {
    // The streamed path itself must be pool-size invariant (not just
    // equal to dense within one pool).
    let x = input_matrix();
    let run = |threads: usize| {
        let pool = Arc::new(ThreadPool::new(threads));
        with_pool(&pool, || {
            let s = Streamed::with_block_rows(InMemorySource::new(x.clone()), 33);
            factorize(&s, 43)
        })
    };
    let base = run(1);
    for threads in [2, 8] {
        assert_identical(&base, &run(threads), &format!("pool {threads}"));
    }
}

#[test]
fn file_source_round_trip_and_factorization() {
    let x = input_matrix();
    let path = std::env::temp_dir().join("srsvd_test_stream_roundtrip.bin");
    let file = srsvd::linalg::stream::write_matrix(&path, &x).expect("write");
    // Bytes survive the disk round trip exactly.
    assert_eq!(dense_bits(&file.materialize().expect("read")), dense_bits(&x));
    // And so does the factorization, at an awkward block size.
    let base = factorize(&x, 44);
    let got = factorize(&Streamed::with_block_rows(file, 41), 44);
    assert_identical(&base, &got, "file-source factorization");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn generator_spill_and_stream_agree() {
    // Generator → direct streaming and generator → spill-to-disk →
    // streaming must produce identical factors.
    let gen = GeneratorSource::new(140, 700, Distribution::Normal, 9).expect("gen");
    let path = std::env::temp_dir().join("srsvd_test_stream_spill.bin");
    let file: FileSource = spill_to_file(&gen, &path, 37).expect("spill");
    let direct = factorize(&Streamed::with_block_rows(gen, 53), 45);
    let spilled = factorize(&Streamed::with_block_rows(file, 29), 45);
    assert_identical(&direct, &spilled, "generator vs spilled file");
    // Both equal the fully materialized dense path.
    let dense = gen.materialize().expect("materialize");
    let base = factorize(&dense, 45);
    assert_identical(&base, &direct, "generator vs dense");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn coordinator_streamed_job_matches_dense_job() {
    let x = input_matrix();
    let run = |input: MatrixInput, pool_threads: usize| {
        let coord = Coordinator::start(CoordinatorConfig {
            native_workers: 2,
            queue_capacity: 8,
            artifact_dir: None,
            pool_threads: Some(pool_threads),
            io_threads: None,
            ..Default::default()
        })
        .expect("coordinator");
        let r = coord
            .submit_blocking(JobSpec {
                input,
                config: cfg(),
                shift: ShiftSpec::MeanCenter,
                engine: EnginePreference::Auto,
                seed: 99,
                score: true,
            })
            .expect("submit");
        let out = r.outcome.expect("job");
        coord.shutdown();
        out
    };
    let stream_cfg = StreamConfig { block_rows: 48, budget_mb: 64, prefetch: true };
    let dense_out = run(MatrixInput::Dense(x.clone()), 2);
    for pool_threads in [1usize, 2, 8] {
        let streamed_out = run(
            MatrixInput::streamed(InMemorySource::new(x.clone()), &stream_cfg),
            pool_threads,
        );
        assert_identical(
            &dense_out.factorization,
            &streamed_out.factorization,
            &format!("coordinator streamed vs dense, pool {pool_threads}"),
        );
        // The streamed scorer must agree with the dense scorer tightly
        // (different expansion of the same quantity).
        let (md, ms) = (dense_out.mse.unwrap(), streamed_out.mse.unwrap());
        assert!(
            (md - ms).abs() < 1e-8 * md.max(1.0),
            "mse dense {md} vs streamed {ms}"
        );
    }
}

/// A source that starts failing after a given row — simulates a backing
/// file truncated mid-sweep.
#[derive(Debug)]
struct FlakySource {
    inner: InMemorySource,
    fail_after_row: usize,
}

impl MatrixSource for FlakySource {
    fn shape(&self) -> (usize, usize) {
        self.inner.shape()
    }

    fn read_rows(&self, row0: usize, nrows: usize, out: &mut [f64]) -> srsvd::util::Result<()> {
        if row0 + nrows > self.fail_after_row {
            return Err(srsvd::util::Error::Io(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "simulated mid-sweep IO failure",
            )));
        }
        self.inner.read_rows(row0, nrows, out)
    }
}

#[test]
fn failing_streamed_source_fails_the_job_not_the_worker() {
    let x = input_matrix();
    let coord = Coordinator::start(CoordinatorConfig {
        native_workers: 1,
        queue_capacity: 8,
        artifact_dir: None,
        pool_threads: Some(2),
        io_threads: None,
        ..Default::default()
    })
    .expect("coordinator");
    let bad = FlakySource { inner: InMemorySource::new(x.clone()), fail_after_row: 60 };
    let job = |input| JobSpec {
        input,
        config: cfg(),
        shift: ShiftSpec::MeanCenter,
        engine: EnginePreference::Auto,
        seed: 1,
        score: false,
    };
    let r = coord
        .submit_blocking(job(MatrixInput::streamed(
            bad,
            &StreamConfig { block_rows: 48, budget_mb: 64, prefetch: true },
        )))
        .expect("submit");
    let err = r.outcome.expect_err("mid-sweep IO failure must fail the job");
    assert!(format!("{err}").contains("panicked"), "{err}");
    // The (single) worker must survive and take the next job.
    let ok = coord
        .submit_blocking(job(MatrixInput::Dense(x)))
        .expect("submit after failure");
    assert!(ok.outcome.is_ok(), "worker must outlive a failing job");
    assert_eq!(coord.metrics().failed, 1);
    coord.shutdown();
}

#[test]
fn budget_derived_blocks_change_nothing() {
    let x = input_matrix();
    let base = factorize(&x, 46);
    // 1 MiB budget on 900 columns → 145 rows/block; 64 MiB → whole matrix.
    for budget_mb in [1usize, 64] {
        let scfg = StreamConfig { block_rows: 0, budget_mb, prefetch: true };
        let s = Streamed::new(InMemorySource::new(x.clone()), &scfg);
        assert!(s.block_rows() >= 1 && s.block_rows() <= 150);
        let got = factorize(&s, 46);
        assert_identical(&base, &got, &format!("budget {budget_mb} MiB"));
    }
}

/// The pass-budget proof: `SourceStats.passes` shows Exact doing
/// `2 + 2q` source passes and Fused `≤ q + 2` for the same job.
#[test]
fn pass_counters_exact_2_plus_2q_fused_at_most_q_plus_2() {
    let x = input_matrix();
    let mu = x.row_means(); // explicit μ: the factorization passes only
    let payload = (150 * 900 * 8) as u64;
    for q in [0usize, 1, 2] {
        let run = |pass_policy| {
            let cfg = SvdConfig::paper(8).with_fixed_power(q).with_pass_policy(pass_policy);
            let s = Streamed::with_block_rows(InMemorySource::new(x.clone()), 64);
            let mut rng = Xoshiro256pp::seed_from_u64(7);
            ShiftedRsvd::new(cfg)
                .factorize(&s, &mu, &mut rng)
                .expect("factorize");
            s.stats()
        };
        let exact = run(PassPolicy::Exact);
        assert_eq!(exact.passes as usize, 2 + 2 * q, "exact q={q}");
        assert_eq!(exact.bytes_read, exact.passes * payload, "exact q={q}");
        let fused = run(PassPolicy::Fused);
        assert!(
            fused.passes as usize <= q + 2,
            "fused q={q}: {} passes exceed the q+2 budget",
            fused.passes
        );
        assert_eq!(fused.passes as usize, q + 2, "fused q={q}");
        assert_eq!(fused.bytes_read, fused.passes * payload, "fused q={q}");
        if q >= 1 {
            assert!(fused.passes < exact.passes, "q={q}");
        }
    }
}

/// Fused reconstruction stays within 1.15× of the optimal rank-k
/// residual (the `rsvd.rs`-style harness bound) on every source kind.
#[test]
fn fused_policy_accuracy_on_all_source_kinds() {
    let cfg = SvdConfig::paper(8).with_fixed_power(2).with_pass_policy(PassPolicy::Fused);

    // One uniform target shared by the dense / in-memory / generator /
    // file paths (the generator is the ground truth for all four).
    let gen = GeneratorSource::new(120, 400, Distribution::Uniform, 3).expect("gen");
    let x = gen.materialize().expect("materialize");
    let mu = x.row_means();
    let xbar = x.subtract_column(&mu);
    let opt = optimal_residual(&xbar, 8);
    let path = std::env::temp_dir().join("srsvd_test_stream_fused_acc.bin");
    let file: FileSource = spill_to_file(&gen, &path, 33).expect("spill");

    let check = |ops: &dyn MatVecOps, what: &str| {
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        let f = ShiftedRsvd::new(cfg).factorize(ops, &mu, &mut rng).expect(what);
        let err = fro_diff(&f.reconstruct(), &xbar);
        assert!(err <= 1.15 * opt, "{what}: err {err} vs optimal {opt}");
    };
    check(&x, "dense");
    check(
        &Streamed::with_block_rows(InMemorySource::new(x.clone()), 23),
        "stream-mem",
    );
    check(&Streamed::with_block_rows(gen, 31), "stream-generator");
    check(&Streamed::with_block_rows(file, 41), "stream-file");
    let _ = std::fs::remove_file(&path);

    // CSR-row source against its own sparse target.
    let mut rng = Xoshiro256pp::seed_from_u64(5);
    let sp = Csr::random(100, 300, 0.15, &mut rng, |r| r.next_uniform() + 0.2);
    let de = sp.to_dense();
    let mu_sp = de.row_means();
    let xbar_sp = de.subtract_column(&mu_sp);
    let opt_sp = optimal_residual(&xbar_sp, 8);
    let s = Streamed::with_block_rows(CsrRowSource::new(sp), 19);
    let mut rng = Xoshiro256pp::seed_from_u64(13);
    let f = ShiftedRsvd::new(cfg).factorize(&s, &mu_sp, &mut rng).expect("csr");
    let err = fro_diff(&f.reconstruct(), &xbar_sp);
    assert!(err <= 1.15 * opt_sp, "stream-csr: err {err} vs optimal {opt_sp}");
}

/// The coordinator aggregates per-job `SourceStats` into the service
/// metrics (`stream_passes` / `stream_bytes_read`, also on `/metrics`).
#[test]
fn coordinator_surfaces_stream_pass_and_byte_counters() {
    let x = input_matrix();
    let coord = Coordinator::start(CoordinatorConfig {
        native_workers: 1,
        queue_capacity: 8,
        artifact_dir: None,
        pool_threads: Some(2),
        io_threads: None,
        ..Default::default()
    })
    .expect("coordinator");
    let r = coord
        .submit_blocking(JobSpec {
            input: MatrixInput::streamed(
                InMemorySource::new(x.clone()),
                &StreamConfig { block_rows: 48, budget_mb: 64, prefetch: true },
            ),
            config: cfg(), // k=12, q=1
            shift: ShiftSpec::MeanCenter,
            engine: EnginePreference::Auto,
            seed: 3,
            score: false,
        })
        .expect("submit");
    r.outcome.expect("job");
    let m = coord.metrics();
    // MeanCenter resolve (1 pass) + exact schedule 2 + 2q with q=1 (4).
    assert_eq!(m.stream_passes, 5, "{m}");
    assert_eq!(m.stream_bytes_read, 5 * (150 * 900 * 8) as u64, "{m}");
    assert!(format!("{m}").contains("stream[passes=5"), "{m}");

    // Dense jobs contribute nothing to the stream counters.
    let r = coord
        .submit_blocking(JobSpec {
            input: MatrixInput::Dense(x),
            config: cfg(),
            shift: ShiftSpec::MeanCenter,
            engine: EnginePreference::Native,
            seed: 3,
            score: false,
        })
        .expect("submit");
    r.outcome.expect("job");
    assert_eq!(coord.metrics().stream_passes, 5);
    coord.shutdown();
}

/// The redesigned stopping criterion, adaptive mode: tolerance-driven
/// factorizations are as deterministic as fixed-q ones — byte-identical
/// across thread-pool sizes (1/2/8), block sizes, and prefetch settings
/// (the dynamic-shift loop runs entirely on the order-stable Gram
/// sweep, so the sweep count itself cannot vary either).
#[test]
fn adaptive_tolerance_is_bit_identical_across_pools_and_blocks() {
    let x = input_matrix();
    let cfg = SvdConfig::paper(12).with_tolerance(1e-3, 16);
    let run = |ops: &dyn MatVecOps| {
        let mut rng = Xoshiro256pp::seed_from_u64(42);
        ShiftedRsvd::new(cfg)
            .factorize_mean_centered(ops, &mut rng)
            .expect("factorize")
    };
    let run_pool = |threads: usize| {
        let pool = Arc::new(ThreadPool::new(threads));
        with_pool(&pool, || {
            let base = run(&x);
            for block_rows in [1usize, 7, 64, 150] {
                for prefetch in [true, false] {
                    let s = Streamed::with_block_rows(InMemorySource::new(x.clone()), block_rows)
                        .with_prefetch(prefetch);
                    assert_identical(
                        &base,
                        &run(&s),
                        &format!("adaptive bl={block_rows}, pool={threads}, prefetch={prefetch}"),
                    );
                }
            }
            base
        })
    };
    let base = run_pool(1);
    for threads in [2, 8] {
        assert_identical(&base, &run_pool(threads), &format!("adaptive pool {threads}"));
    }
}

/// `with_fixed_power(q)` replaced the removed `with_power(q)` shim:
/// same criterion whether spelled through the builder or the enum, and
/// byte-identical factors, so fixed-q clients migrated with zero
/// numerical drift.
#[test]
fn fixed_power_reproduces_pre_redesign_factors_byte_for_byte() {
    let x = input_matrix();
    let new = {
        let mut rng = Xoshiro256pp::seed_from_u64(42);
        ShiftedRsvd::new(SvdConfig::paper(12).with_fixed_power(1))
            .factorize_mean_centered(&x, &mut rng)
            .expect("new api")
    };
    let old = {
        let mut rng = Xoshiro256pp::seed_from_u64(42);
        let cfg = SvdConfig { stop: StopCriterion::FixedPower { q: 1 }, ..SvdConfig::paper(12) };
        ShiftedRsvd::new(cfg).factorize_mean_centered(&x, &mut rng).expect("enum spelling")
    };
    assert_identical(&new, &old, "fixed-power spellings");
}

/// Adaptive pass budget on streamed sources: `SourceStats.passes` is
/// exactly `sweeps_used + 3` — one ‖X̄‖²_F pass, one Gram sweep per
/// power sweep, one capture, one projection — on every source kind
/// (explicit μ, so no mean-resolve pass).
#[test]
fn adaptive_pass_counters_match_reported_sweeps_on_all_source_kinds() {
    let cfg = SvdConfig::paper(8).with_tolerance(1e-3, 16);

    let gen = GeneratorSource::new(120, 400, Distribution::Uniform, 3).expect("gen");
    let x = gen.materialize().expect("materialize");
    let mu = x.row_means();
    let path = std::env::temp_dir().join("srsvd_test_stream_adaptive_passes.bin");
    let file: FileSource = spill_to_file(&gen, &path, 33).expect("spill");

    let s = Streamed::with_block_rows(InMemorySource::new(x.clone()), 23);
    let mut rng = Xoshiro256pp::seed_from_u64(11);
    let (_, mem_rep) = ShiftedRsvd::new(cfg)
        .factorize_with_report(&s, &mu, &mut rng)
        .expect("stream-mem");
    assert!(
        mem_rep.sweeps_used >= 1 && mem_rep.sweeps_used <= 16,
        "sweeps {}",
        mem_rep.sweeps_used
    );
    let pve = mem_rep.achieved_pve.expect("adaptive mode reports a pve");
    assert!(pve > 0.0 && pve <= 1.0, "pve {pve}");
    assert_eq!(s.stats().passes as usize, mem_rep.sweeps_used + 3, "stream-mem");

    // Same matrix spilled to a file: same sweep count, same pass budget.
    let s = Streamed::with_block_rows(file, 41);
    let mut rng = Xoshiro256pp::seed_from_u64(11);
    let (_, file_rep) = ShiftedRsvd::new(cfg)
        .factorize_with_report(&s, &mu, &mut rng)
        .expect("stream-file");
    assert_eq!(file_rep.sweeps_used, mem_rep.sweeps_used, "file vs mem sweeps");
    assert_eq!(s.stats().passes as usize, file_rep.sweeps_used + 3, "stream-file");
    let _ = std::fs::remove_file(&path);

    // CSR rows, against its own sparse target.
    let mut rng = Xoshiro256pp::seed_from_u64(5);
    let sp = Csr::random(100, 300, 0.15, &mut rng, |r| r.next_uniform() + 0.2);
    let mu_sp = sp.to_dense().row_means();
    let s = Streamed::with_block_rows(CsrRowSource::new(sp), 19);
    let mut rng = Xoshiro256pp::seed_from_u64(13);
    let (_, csr_rep) = ShiftedRsvd::new(cfg)
        .factorize_with_report(&s, &mu_sp, &mut rng)
        .expect("stream-csr");
    assert!(csr_rep.sweeps_used >= 1);
    assert_eq!(s.stats().passes as usize, csr_rep.sweeps_used + 3, "stream-csr");
}
