//! Determinism under parallelism: the whole point of the chunked pool
//! design is that results are **bit-identical** for every pool size,
//! because every parallel kernel partitions output rows and each row is
//! accumulated in the exact serial order. These tests pin that contract
//! at the `Factorization` level (u, s, v compared bit-for-bit) for pool
//! sizes 1, 2 and 8, on both dense and CSR inputs, plus the coordinator
//! path end-to-end.

use std::sync::Arc;

use srsvd::coordinator::{
    Coordinator, CoordinatorConfig, EnginePreference, JobSpec, MatrixInput, ShiftSpec,
};
use srsvd::linalg::{Csr, Dense};
use srsvd::parallel::{with_pool, ThreadPool};
use srsvd::rng::{Rng, Xoshiro256pp};
use srsvd::svd::{Factorization, ShiftedRsvd, SvdConfig};

fn dense_bits(x: &Dense) -> Vec<u64> {
    x.data().iter().map(|v| v.to_bits()).collect()
}

fn fact_bits(f: &Factorization) -> (Vec<u64>, Vec<u64>, Vec<u64>) {
    (
        dense_bits(&f.u),
        f.s.iter().map(|v| v.to_bits()).collect(),
        dense_bits(&f.v),
    )
}

fn assert_identical(a: &Factorization, b: &Factorization, what: &str) {
    let (au, as_, av) = fact_bits(a);
    let (bu, bs, bv) = fact_bits(b);
    assert_eq!(au, bu, "{what}: u bytes differ");
    assert_eq!(as_, bs, "{what}: s bytes differ");
    assert_eq!(av, bv, "{what}: v bytes differ");
}

/// Big enough that the internal products clear the parallel threshold
/// (m·n·K ≈ 150·900·24 ≈ 3.2M flops for the sampling pass alone).
fn dense_input() -> Dense {
    let mut rng = Xoshiro256pp::seed_from_u64(0xD15E);
    Dense::from_fn(150, 900, |_, _| rng.next_uniform())
}

fn sparse_input() -> Csr {
    let mut rng = Xoshiro256pp::seed_from_u64(0x5BA6);
    Csr::random(500, 4000, 0.06, &mut rng, |r| r.next_uniform() + 0.1)
}

#[test]
fn dense_factorization_identical_for_pool_sizes_1_2_8() {
    let x = dense_input();
    let cfg = SvdConfig::paper(12).with_fixed_power(1);
    let run = |threads: usize| -> Factorization {
        let pool = Arc::new(ThreadPool::new(threads));
        with_pool(&pool, || {
            let mut rng = Xoshiro256pp::seed_from_u64(42);
            ShiftedRsvd::new(cfg)
                .factorize_mean_centered(&x, &mut rng)
                .expect("factorize")
        })
    };
    let base = run(1);
    for threads in [2, 8] {
        let got = run(threads);
        assert_identical(&base, &got, &format!("dense, {threads} threads"));
    }
}

#[test]
fn streamed_factorization_identical_for_pool_sizes_1_2_8() {
    // The out-of-core path shares the determinism contract: block
    // sweeps reuse the same pool-aware kernels (full parity suite with
    // block-size sweeps lives in tests/stream.rs).
    let x = dense_input();
    let cfg = SvdConfig::paper(12).with_fixed_power(1);
    let run = |threads: usize| -> Factorization {
        let pool = Arc::new(ThreadPool::new(threads));
        with_pool(&pool, || {
            let s = srsvd::linalg::Streamed::with_block_rows(
                srsvd::linalg::InMemorySource::new(x.clone()),
                37,
            );
            let mut rng = Xoshiro256pp::seed_from_u64(42);
            ShiftedRsvd::new(cfg)
                .factorize_mean_centered(&s, &mut rng)
                .expect("factorize")
        })
    };
    let base = run(1);
    for threads in [2, 8] {
        let got = run(threads);
        assert_identical(&base, &got, &format!("streamed, {threads} threads"));
    }
}

#[test]
fn sparse_factorization_identical_for_pool_sizes_1_2_8() {
    let x = sparse_input();
    let cfg = SvdConfig::paper(10).with_fixed_power(1);
    let run = |threads: usize| -> Factorization {
        let pool = Arc::new(ThreadPool::new(threads));
        with_pool(&pool, || {
            let mut rng = Xoshiro256pp::seed_from_u64(43);
            ShiftedRsvd::new(cfg)
                .factorize_mean_centered(&x, &mut rng)
                .expect("factorize")
        })
    };
    let base = run(1);
    for threads in [2, 8] {
        let got = run(threads);
        assert_identical(&base, &got, &format!("sparse, {threads} threads"));
    }
}

#[test]
fn raw_kernels_identical_across_pools_on_awkward_shapes() {
    // Odd, non-chunk-aligned shapes; sizes above the parallel threshold.
    let mut rng = Xoshiro256pp::seed_from_u64(7);
    let a = Dense::gaussian(131, 517, &mut rng);
    let b = Dense::gaussian(517, 67, &mut rng);
    let bt = Dense::gaussian(131, 67, &mut rng);
    let u: Vec<f64> = (0..131).map(|_| rng.next_gaussian()).collect();
    let v: Vec<f64> = (0..67).map(|_| rng.next_gaussian()).collect();

    let run = |threads: usize| -> Vec<Vec<u64>> {
        let pool = Arc::new(ThreadPool::new(threads));
        with_pool(&pool, || {
            vec![
                dense_bits(&srsvd::linalg::matmul(&a, &b)),
                dense_bits(&srsvd::linalg::matmul_rank1(&a, &b, &u, &v)),
                dense_bits(&srsvd::linalg::gemm::tmatmul(&a, &bt)),
            ]
        })
    };
    let base = run(1);
    for threads in [2, 3, 8] {
        assert_eq!(base, run(threads), "{threads} threads");
    }
}

/// End-to-end through the service: two coordinators with different
/// shared-pool sizes must produce byte-identical factorizations for the
/// same seeded job.
#[test]
fn coordinator_factorizations_identical_across_pool_sizes() {
    let job = || {
        let mut rng = Xoshiro256pp::seed_from_u64(0xC0DE);
        JobSpec {
            input: MatrixInput::Dense(Dense::from_fn(120, 700, |_, _| rng.next_uniform())),
            config: SvdConfig::paper(8).with_fixed_power(1),
            shift: ShiftSpec::MeanCenter,
            engine: EnginePreference::Native,
            seed: 99,
            score: true,
        }
    };
    let run = |pool_threads: usize| {
        let coord = Coordinator::start(CoordinatorConfig {
            native_workers: 2,
            queue_capacity: 8,
            artifact_dir: None,
            pool_threads: Some(pool_threads),
        })
        .expect("coordinator");
        let r = coord.submit_blocking(job()).expect("submit");
        let out = r.outcome.expect("job");
        coord.shutdown();
        out
    };
    let base = run(1);
    for threads in [2, 8] {
        let got = run(threads);
        assert_identical(
            &base.factorization,
            &got.factorization,
            &format!("coordinator, pool {threads}"),
        );
        // MSE is computed from identical factors — must match exactly.
        assert_eq!(base.mse, got.mse);
    }
}
