//! Determinism under parallelism: the whole point of the chunked pool
//! design is that results are **bit-identical** for every pool size,
//! because every parallel kernel partitions output rows and each row is
//! accumulated in the exact serial order. These tests pin that contract
//! at the `Factorization` level (u, s, v compared bit-for-bit) for pool
//! sizes 1, 2 and 8, on both dense and CSR inputs, plus the coordinator
//! path end-to-end.

use std::sync::Arc;
use std::time::Duration;

use srsvd::coordinator::{
    Coordinator, CoordinatorConfig, EnginePreference, JobSpec, MatrixInput, ShiftSpec,
};
use srsvd::linalg::gemm::kernels::with_simd;
use srsvd::linalg::gemm::Simd;
use srsvd::linalg::{Csr, Dense, InMemorySource, MatrixSource, StreamConfig, Streamed};
use srsvd::parallel::{with_pool, ThreadPool};
use srsvd::rng::{Rng, Xoshiro256pp};
use srsvd::svd::{Factorization, Precision, ShiftedRsvd, SvdConfig};

fn dense_bits(x: &Dense) -> Vec<u64> {
    x.data().iter().map(|v| v.to_bits()).collect()
}

fn fact_bits(f: &Factorization) -> (Vec<u64>, Vec<u64>, Vec<u64>) {
    (
        dense_bits(&f.u),
        f.s.iter().map(|v| v.to_bits()).collect(),
        dense_bits(&f.v),
    )
}

fn assert_identical(a: &Factorization, b: &Factorization, what: &str) {
    let (au, as_, av) = fact_bits(a);
    let (bu, bs, bv) = fact_bits(b);
    assert_eq!(au, bu, "{what}: u bytes differ");
    assert_eq!(as_, bs, "{what}: s bytes differ");
    assert_eq!(av, bv, "{what}: v bytes differ");
}

/// Big enough that the internal products clear the parallel threshold
/// (m·n·K ≈ 150·900·24 ≈ 3.2M flops for the sampling pass alone).
fn dense_input() -> Dense {
    let mut rng = Xoshiro256pp::seed_from_u64(0xD15E);
    Dense::from_fn(150, 900, |_, _| rng.next_uniform())
}

fn sparse_input() -> Csr {
    let mut rng = Xoshiro256pp::seed_from_u64(0x5BA6);
    Csr::random(500, 4000, 0.06, &mut rng, |r| r.next_uniform() + 0.1)
}

#[test]
fn dense_factorization_identical_for_pool_sizes_1_2_8() {
    let x = dense_input();
    let cfg = SvdConfig::paper(12).with_fixed_power(1);
    let run = |threads: usize| -> Factorization {
        let pool = Arc::new(ThreadPool::new(threads));
        with_pool(&pool, || {
            let mut rng = Xoshiro256pp::seed_from_u64(42);
            ShiftedRsvd::new(cfg)
                .factorize_mean_centered(&x, &mut rng)
                .expect("factorize")
        })
    };
    let base = run(1);
    for threads in [2, 8] {
        let got = run(threads);
        assert_identical(&base, &got, &format!("dense, {threads} threads"));
    }
}

#[test]
fn streamed_factorization_identical_for_pool_sizes_1_2_8() {
    // The out-of-core path shares the determinism contract: block
    // sweeps reuse the same pool-aware kernels (full parity suite with
    // block-size sweeps lives in tests/stream.rs).
    let x = dense_input();
    let cfg = SvdConfig::paper(12).with_fixed_power(1);
    let run = |threads: usize| -> Factorization {
        let pool = Arc::new(ThreadPool::new(threads));
        with_pool(&pool, || {
            let s = srsvd::linalg::Streamed::with_block_rows(
                srsvd::linalg::InMemorySource::new(x.clone()),
                37,
            );
            let mut rng = Xoshiro256pp::seed_from_u64(42);
            ShiftedRsvd::new(cfg)
                .factorize_mean_centered(&s, &mut rng)
                .expect("factorize")
        })
    };
    let base = run(1);
    for threads in [2, 8] {
        let got = run(threads);
        assert_identical(&base, &got, &format!("streamed, {threads} threads"));
    }
}

#[test]
fn sparse_factorization_identical_for_pool_sizes_1_2_8() {
    let x = sparse_input();
    let cfg = SvdConfig::paper(10).with_fixed_power(1);
    let run = |threads: usize| -> Factorization {
        let pool = Arc::new(ThreadPool::new(threads));
        with_pool(&pool, || {
            let mut rng = Xoshiro256pp::seed_from_u64(43);
            ShiftedRsvd::new(cfg)
                .factorize_mean_centered(&x, &mut rng)
                .expect("factorize")
        })
    };
    let base = run(1);
    for threads in [2, 8] {
        let got = run(threads);
        assert_identical(&base, &got, &format!("sparse, {threads} threads"));
    }
}

#[test]
fn raw_kernels_identical_across_pools_on_awkward_shapes() {
    // Odd, non-chunk-aligned shapes; sizes above the parallel threshold.
    let mut rng = Xoshiro256pp::seed_from_u64(7);
    let a = Dense::gaussian(131, 517, &mut rng);
    let b = Dense::gaussian(517, 67, &mut rng);
    let bt = Dense::gaussian(131, 67, &mut rng);
    let u: Vec<f64> = (0..131).map(|_| rng.next_gaussian()).collect();
    let v: Vec<f64> = (0..67).map(|_| rng.next_gaussian()).collect();

    let run = |threads: usize| -> Vec<Vec<u64>> {
        let pool = Arc::new(ThreadPool::new(threads));
        with_pool(&pool, || {
            vec![
                dense_bits(&srsvd::linalg::matmul(&a, &b)),
                dense_bits(&srsvd::linalg::matmul_rank1(&a, &b, &u, &v)),
                dense_bits(&srsvd::linalg::gemm::tmatmul(&a, &bt)),
            ]
        })
    };
    let base = run(1);
    for threads in [2, 3, 8] {
        assert_eq!(base, run(threads), "{threads} threads");
    }
}

/// End-to-end through the service: two coordinators with different
/// shared-pool sizes must produce byte-identical factorizations for the
/// same seeded job.
#[test]
fn coordinator_factorizations_identical_across_pool_sizes() {
    let job = || {
        let mut rng = Xoshiro256pp::seed_from_u64(0xC0DE);
        JobSpec {
            input: MatrixInput::Dense(Dense::from_fn(120, 700, |_, _| rng.next_uniform())),
            config: SvdConfig::paper(8).with_fixed_power(1),
            shift: ShiftSpec::MeanCenter,
            engine: EnginePreference::Native,
            seed: 99,
            score: true,
        }
    };
    let run = |pool_threads: usize| {
        let coord = Coordinator::start(CoordinatorConfig {
            native_workers: 2,
            queue_capacity: 8,
            artifact_dir: None,
            pool_threads: Some(pool_threads),
            io_threads: None,
            ..Default::default()
        })
        .expect("coordinator");
        let r = coord.submit_blocking(job()).expect("submit");
        let out = r.outcome.expect("job");
        coord.shutdown();
        out
    };
    let base = run(1);
    for threads in [2, 8] {
        let got = run(threads);
        assert_identical(
            &base.factorization,
            &got.factorization,
            &format!("coordinator, pool {threads}"),
        );
        // MSE is computed from identical factors — must match exactly.
        assert_eq!(base.mse, got.mse);
    }
}

/// The Exact kernel tier must be byte-identical across SIMD modes as
/// well as pool sizes: the AVX2 exact kernels reproduce the scalar
/// accumulation order lane-for-lane, so `simd on/off × threads 1/2/8`
/// is one equivalence class on dense, streamed, and sparse inputs.
/// (`with_simd(Avx2)` means "best available" — on non-AVX2 hardware it
/// degrades to scalar and the comparison is trivially exact.)
#[test]
fn exact_tier_identical_across_simd_modes_and_pool_sizes() {
    let dense = dense_input();
    let sparse = sparse_input();
    let dcfg = SvdConfig::paper(12).with_fixed_power(1);
    let scfg = SvdConfig::paper(10).with_fixed_power(1);
    let run = |simd: Simd, threads: usize| -> Vec<Factorization> {
        let pool = Arc::new(ThreadPool::new(threads));
        with_pool(&pool, || {
            with_simd(simd, || {
                let mut r1 = Xoshiro256pp::seed_from_u64(42);
                let f1 = ShiftedRsvd::new(dcfg)
                    .factorize_mean_centered(&dense, &mut r1)
                    .expect("dense");
                let s = Streamed::with_block_rows(InMemorySource::new(dense.clone()), 37);
                let mut r2 = Xoshiro256pp::seed_from_u64(42);
                let f2 = ShiftedRsvd::new(dcfg)
                    .factorize_mean_centered(&s, &mut r2)
                    .expect("streamed");
                let mut r3 = Xoshiro256pp::seed_from_u64(43);
                let f3 = ShiftedRsvd::new(scfg)
                    .factorize_mean_centered(&sparse, &mut r3)
                    .expect("sparse");
                vec![f1, f2, f3]
            })
        })
    };
    let base = run(Simd::Scalar, 1);
    for simd in [Simd::Scalar, Simd::Avx2] {
        for threads in [1, 2, 8] {
            let got = run(simd, threads);
            let names = ["dense", "streamed", "sparse"];
            for (i, (name, g)) in names.iter().zip(&got).enumerate() {
                assert_identical(
                    &base[i],
                    g,
                    &format!("{name}, simd {:?}, {threads} threads", simd),
                );
            }
        }
    }
}

/// Rank-k reconstruction `u · diag(s) · vᵀ`, the sign-invariant way to
/// compare two factorizations that are only ulp-level apart.
fn reconstruct(f: &Factorization) -> Vec<f64> {
    let (m, k) = f.u.shape();
    let (n, k2) = f.v.shape();
    assert_eq!(k, k2, "u and v rank mismatch");
    let (ud, vd) = (f.u.data(), f.v.data());
    let mut out = vec![0.0; m * n];
    for i in 0..m {
        for t in 0..k {
            let c = ud[i * k + t] * f.s[t];
            for j in 0..n {
                out[i * n + j] += c * vd[j * k + t];
            }
        }
    }
    out
}

/// The Fast tier trades byte-identity for FMA throughput, but only in
/// the last ulps: on a seeded fig1-style input its singular values must
/// track the Exact tier to 1e-12 (relative) and the rank-k
/// reconstruction to 1e-9 — far below any accuracy the experiments
/// report. On hardware without AVX2/FMA the Fast tier falls back to the
/// scalar kernels and the comparison is exact.
#[test]
fn fast_tier_tracks_exact_within_tolerance() {
    let x = dense_input();
    let run = |p: Precision| -> Factorization {
        let cfg = SvdConfig::paper(12).with_fixed_power(2).with_precision(p);
        let mut rng = Xoshiro256pp::seed_from_u64(0xF16);
        ShiftedRsvd::new(cfg)
            .factorize_mean_centered(&x, &mut rng)
            .expect("factorize")
    };
    let exact = run(Precision::Exact);
    let fast = run(Precision::Fast);
    let scale = exact.s[0];
    assert!(scale > 0.0, "degenerate spectrum");
    for (i, (a, b)) in exact.s.iter().zip(&fast.s).enumerate() {
        assert!(
            (a - b).abs() <= 1e-12 * scale,
            "s[{i}]: exact {a} vs fast {b}"
        );
    }
    let re = reconstruct(&exact);
    let rf = reconstruct(&fast);
    for (idx, (a, b)) in re.iter().zip(&rf).enumerate() {
        assert!(
            (a - b).abs() <= 1e-9 * scale,
            "reconstruction[{idx}]: exact {a} vs fast {b}"
        );
    }
}

/// A matrix source whose every read sleeps — a stand-in for slow disk
/// or network I/O.
#[derive(Debug)]
struct SlowSource {
    inner: InMemorySource,
    delay: Duration,
}

impl MatrixSource for SlowSource {
    fn shape(&self) -> (usize, usize) {
        self.inner.shape()
    }
    fn read_rows(&self, row0: usize, nrows: usize, out: &mut [f64]) -> srsvd::util::Result<()> {
        std::thread::sleep(self.delay);
        self.inner.read_rows(row0, nrows, out)
    }
}

/// Pool separation end-to-end: a streamed job grinding through seconds
/// of blocking reads (on the io pool) must not starve a concurrent
/// dense job of cpu-pool workers. The overlap is the assertion — the
/// dense job completes while the slow job is still running.
#[test]
fn slow_streamed_io_does_not_starve_dense_compute() {
    let coord = Coordinator::start(CoordinatorConfig {
        native_workers: 2,
        queue_capacity: 8,
        artifact_dir: None,
        pool_threads: Some(2),
        io_threads: Some(1),
        ..Default::default()
    })
    .expect("coordinator");

    let x = dense_input();
    let slow = SlowSource {
        inner: InMemorySource::new(x.clone()),
        delay: Duration::from_millis(25),
    };
    // 15 blocks per pass, 2 + 2q = 6 factorization passes plus the
    // mean pass: >2 s of pure sleeping reads.
    let slow_spec = JobSpec {
        input: MatrixInput::streamed(
            slow,
            &StreamConfig { block_rows: 10, budget_mb: 64, prefetch: true },
        ),
        config: SvdConfig::paper(8).with_fixed_power(2),
        shift: ShiftSpec::MeanCenter,
        engine: EnginePreference::Native,
        seed: 7,
        score: false,
    };
    let dense_spec = JobSpec {
        input: MatrixInput::Dense(x),
        config: SvdConfig::paper(8).with_fixed_power(1),
        shift: ShiftSpec::MeanCenter,
        engine: EnginePreference::Native,
        seed: 7,
        score: false,
    };
    let slow_h = coord.submit(slow_spec).expect("submit slow");
    let dense_h = coord.submit(dense_spec).expect("submit dense");
    let r = dense_h
        .wait_timeout(Duration::from_secs(60))
        .expect("dense job starved: streamed io is blocking the cpu pool");
    r.outcome.expect("dense job");
    match slow_h.wait_timeout(Duration::from_millis(0)) {
        Err(srsvd::util::Error::Timeout(_)) => {}
        Ok(_) => panic!("slow job finished before the dense job — no overlap to observe"),
        Err(e) => panic!("slow job failed early: {e}"),
    }
    let r = slow_h.wait().expect("slow job result");
    r.outcome.expect("slow job");
    coord.shutdown();
}
