//! Chaos suite: end-to-end behaviour under injected faults.
//!
//! Every test arms the process-global fail-point registry
//! (`srsvd::util::faults`), so the whole file serializes on a local
//! mutex — the crate-internal test lock is not visible to integration
//! binaries, and this binary's registry is its own process anyway.
//!
//! What is pinned here, layer by layer:
//!
//! * transient `stream.read` errors at `p = 1.0` complete through the
//!   typed retry policy with **byte-identical** factors, on file and
//!   CSR-row sources, across thread pools 1/2/8 and prefetch on/off;
//! * a `die_after` crash mid-sweep, then a restart with the same spec
//!   and seed, resumes from the checkpoint and reproduces the
//!   uninterrupted factors bit for bit;
//! * an exhausted retry budget fails the *job* with a typed I/O error
//!   (attempt count included) — the worker survives;
//! * a worker panic surfaces as `Error::Service` carrying the job id
//!   and the panic message;
//! * a torn HTTP response write re-parks the claimed result and the
//!   client's policy-driven GET retry recovers it intact;
//! * backpressure 503s carry `Retry-After`, and `submit_retrying`
//!   honors it until the queue drains;
//! * journaled accepted-but-unfinished jobs are re-run when a server
//!   restarts on the same journal directory.

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use srsvd::coordinator::{
    Coordinator, CoordinatorConfig, EnginePreference, JobSpec, MatrixInput, ShiftSpec,
};
use srsvd::data::Distribution;
use srsvd::linalg::stream::{
    spill_to_file, CsrRowSource, FileSource, GeneratorSource, MatrixSource, StreamConfig, Streamed,
};
use srsvd::linalg::{Csr, Dense};
use srsvd::parallel::{with_pool, ThreadPool};
use srsvd::rng::{Rng, Xoshiro256pp};
use srsvd::server::client::{SubmitOutcome, WaitOutcome};
use srsvd::server::protocol::{generator_input, JobRequest};
use srsvd::server::{Client, Server, ServerConfig};
use srsvd::svd::{Checkpointer, Factorization, ShiftedRsvd, SvdConfig};
use srsvd::util::faults;
use srsvd::util::retry::RetryPolicy;

/// The fail-point registry is process-global: every test in this
/// binary that arms it holds this guard for its whole body.
fn locked() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Zero-sleep retry policy: chaos tests must converge fast, and the
/// backoff arithmetic is covered by the unit tests.
fn fast_retry(max_attempts: u32) -> RetryPolicy {
    RetryPolicy { max_attempts, backoff_base_ms: 0, backoff_max_ms: 0, jitter: false }
}

fn factor_bits(f: &Factorization) -> (Vec<u64>, Vec<u64>, Vec<u64>) {
    let b = |d: &Dense| d.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>();
    (b(&f.u), f.s.iter().map(|v| v.to_bits()).collect(), b(&f.v))
}

fn temp_dir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("srsvd_faults_{}_{name}", std::process::id()));
    let _ = std::fs::create_dir_all(&d);
    d
}

/// The two streamed source kinds under test, behind one constructor so
/// the pool × prefetch grids below stay readable.
fn file_source(path: &std::path::Path) -> FileSource {
    let gen = GeneratorSource::new(60, 200, Distribution::Uniform, 17).unwrap();
    spill_to_file(&gen, path, 16).unwrap()
}

fn csr_source() -> CsrRowSource {
    let mut rng = Xoshiro256pp::seed_from_u64(23);
    CsrRowSource::new(Csr::random(60, 200, 0.2, &mut rng, |r| r.next_uniform() + 0.1))
}

fn factorize(ops: &dyn srsvd::svd::MatVecOps, cfg: SvdConfig, seed: u64) -> Factorization {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    ShiftedRsvd::new(cfg)
        .factorize_mean_centered(ops, &mut rng)
        .expect("factorize")
}

#[test]
fn transient_read_errors_complete_byte_identical_across_pools_and_sources() {
    let _g = locked();
    faults::disarm();
    let cfg = SvdConfig::paper(6).with_fixed_power(2);
    let path = temp_dir("transient").join("src.bin");
    let file = file_source(&path);
    let csr = csr_source();
    // `stream.read` fires inside FileSource; the prefetch pipeline's
    // own `stream.prefetch` site covers sources (CSR, generator) that
    // have no I/O of their own.
    let cases: [(&str, &dyn MatrixSource, &str, &[bool]); 2] = [
        ("file", &file, "stream.read=err:2@1.0", &[true, false]),
        ("csr", &csr, "stream.prefetch=err:2@1.0", &[true]),
    ];
    for (name, src, spec, prefetches) in cases {
        // Clean baseline, then the same factorization with the read
        // site failing twice at p = 1.0: the retry loop must absorb
        // the failures without perturbing a single bit.
        let base = factorize(&Streamed::with_block_rows(src, 13), cfg, 71);
        for threads in [1usize, 2, 8] {
            let pool = Arc::new(ThreadPool::new(threads));
            with_pool(&pool, || {
                for &prefetch in prefetches {
                    faults::arm(spec).unwrap();
                    let injected_before = faults::injected_count();
                    let s = Streamed::with_block_rows(src, 13)
                        .with_prefetch(prefetch)
                        .with_retry(fast_retry(4));
                    let got = factorize(&s, cfg, 71);
                    faults::disarm();
                    assert!(
                        faults::injected_count() >= injected_before + 2,
                        "{name}: faults never fired"
                    );
                    assert!(s.stats().retries >= 2, "{name}: retries not counted");
                    assert_eq!(
                        factor_bits(&base),
                        factor_bits(&got),
                        "{name}: retried factors differ (pool={threads}, prefetch={prefetch})"
                    );
                }
            });
        }
    }
    let _ = std::fs::remove_dir_all(path.parent().unwrap());
}

#[test]
fn crash_mid_sweep_resumes_byte_identical_across_pools_and_sources() {
    let _g = locked();
    faults::disarm();
    let cfg = SvdConfig::paper(6).with_fixed_power(3);
    let dir = temp_dir("crash_resume");
    let path = dir.join("src.bin");
    let file = file_source(&path);
    let csr = csr_source();
    let mut tag = 0x0FEE_D000u64;
    for (name, src) in [("file", &file as &dyn MatrixSource), ("csr", &csr)] {
        let base = factorize(&Streamed::with_block_rows(src, 17), cfg, 83);
        for threads in [1usize, 2, 8] {
            let pool = Arc::new(ThreadPool::new(threads));
            with_pool(&pool, || {
                for prefetch in [true, false] {
                    tag += 1;
                    let ckpt = Checkpointer::new(&dir, tag);
                    // Crash at the top of sweep 2: sweep 1's checkpoint
                    // is on disk, the process "dies" mid-job.
                    faults::arm("svd.sweep=die_after:2").unwrap();
                    let engine = ShiftedRsvd::new(cfg).with_checkpoint(ckpt.clone());
                    let s = Streamed::with_block_rows(src, 17).with_prefetch(prefetch);
                    let crashed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        engine.factorize_mean_centered(&s, &mut Xoshiro256pp::seed_from_u64(83))
                    }));
                    faults::disarm();
                    let payload = crashed.expect_err("die_after must panic");
                    let msg = payload
                        .downcast_ref::<String>()
                        .map(|s| s.as_str())
                        .or_else(|| payload.downcast_ref::<&str>().copied())
                        .unwrap_or("");
                    assert!(msg.contains(faults::CRASH_MARKER), "{name}: {msg:?}");
                    // Restart: same spec, same seed, same tag.
                    let s = Streamed::with_block_rows(src, 17).with_prefetch(prefetch);
                    let resumed = ShiftedRsvd::new(cfg)
                        .with_checkpoint(ckpt)
                        .factorize_mean_centered(&s, &mut Xoshiro256pp::seed_from_u64(83))
                        .expect("resume");
                    assert_eq!(
                        factor_bits(&base),
                        factor_bits(&resumed),
                        "{name}: resumed factors differ (pool={threads}, prefetch={prefetch})"
                    );
                }
            });
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn exhausted_retry_budget_fails_the_job_typed_and_the_worker_survives() {
    let _g = locked();
    faults::disarm();
    let coord = Coordinator::start(CoordinatorConfig {
        native_workers: 1,
        queue_capacity: 8,
        artifact_dir: None,
        pool_threads: Some(2),
        io_threads: None,
        checkpoint_dir: None,
        retry: fast_retry(3),
    })
    .unwrap();
    let gen = GeneratorSource::new(40, 120, Distribution::Uniform, 5).unwrap();
    let x = gen.materialize().unwrap();
    // Every prefetched read fails, forever: 3 attempts per block, then
    // the reader thread gives up, the panic is re-raised on the worker,
    // and the coordinator maps it to a typed I/O error.
    faults::arm("stream.prefetch=err@1.0").unwrap();
    let r = coord
        .submit_blocking(JobSpec {
            input: MatrixInput::streamed(
                gen,
                &StreamConfig { block_rows: 16, budget_mb: 64, prefetch: true },
            ),
            config: SvdConfig::paper(4),
            shift: ShiftSpec::MeanCenter,
            engine: EnginePreference::Native,
            seed: 2,
            score: false,
        })
        .unwrap();
    faults::disarm();
    let err = r.outcome.expect_err("all-reads-fail job must fail");
    let text = format!("{err}");
    assert!(matches!(err, srsvd::util::Error::Io(_)), "typed Io, got: {text}");
    assert!(text.contains("attempt"), "attempt count missing: {text}");
    assert!(text.contains("srsvd-fault"), "injected marker missing: {text}");
    // The worker survives and the retry traffic reaches the metrics.
    let m = coord.metrics();
    assert_eq!(m.failed, 1);
    assert!(m.stream_retries >= 2, "{m:?}");
    assert!(m.faults_injected >= 3, "{m:?}");
    let ok = coord
        .submit_blocking(JobSpec {
            input: MatrixInput::Dense(x),
            config: SvdConfig::paper(4),
            shift: ShiftSpec::MeanCenter,
            engine: EnginePreference::Native,
            seed: 2,
            score: false,
        })
        .unwrap();
    assert!(ok.outcome.is_ok(), "worker must outlive the failed job");
    coord.shutdown();
}

#[test]
fn worker_panic_maps_to_service_error_with_job_id_and_message() {
    let _g = locked();
    faults::disarm();
    let coord = Coordinator::start(CoordinatorConfig {
        native_workers: 1,
        queue_capacity: 4,
        artifact_dir: None,
        pool_threads: Some(2),
        io_threads: None,
        ..Default::default()
    })
    .unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(3);
    let x = Dense::from_fn(20, 50, |_, _| rng.next_uniform());
    faults::arm("svd.sweep=die_after:1").unwrap();
    let r = coord
        .submit_blocking(JobSpec {
            input: MatrixInput::Dense(x),
            config: SvdConfig::paper(3).with_fixed_power(1),
            shift: ShiftSpec::MeanCenter,
            engine: EnginePreference::Native,
            seed: 4,
            score: false,
        })
        .unwrap();
    faults::disarm();
    let err = r.outcome.expect_err("injected crash must fail the job");
    let text = format!("{err}");
    assert!(
        matches!(err, srsvd::util::Error::Service(_)),
        "typed Service, got: {text}"
    );
    assert!(text.contains("job panicked"), "{text}");
    assert!(text.contains("srsvd-fault: injected crash"), "{text}");
    assert!(text.contains(&format!("{}", r.id)), "job id missing: {text}");
    coord.shutdown();
}

fn start_server(queue_capacity: usize, scfg_extra: impl FnOnce(&mut ServerConfig)) -> Server {
    let coord = Arc::new(
        Coordinator::start(CoordinatorConfig {
            native_workers: 1,
            queue_capacity,
            artifact_dir: None,
            pool_threads: Some(2),
            io_threads: None,
            ..Default::default()
        })
        .unwrap(),
    );
    let mut scfg = ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() };
    scfg_extra(&mut scfg);
    Server::bind(coord, &scfg, StreamConfig::default()).unwrap()
}

fn wait_for(deadline: Duration, what: &str, mut done: impl FnMut() -> bool) {
    let end = Instant::now() + deadline;
    while !done() {
        assert!(Instant::now() < end, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(25));
    }
}

#[test]
fn torn_response_write_is_recovered_by_the_client_retry() {
    let _g = locked();
    faults::disarm();
    let server = start_server(16, |_| {});
    let mut client = Client::connect(&server.local_addr().to_string()).unwrap();
    let mut req = JobRequest::new(
        generator_input(30, 40, Distribution::Uniform, 6, None, None),
        3,
    );
    req.engine = EnginePreference::Native;
    let SubmitOutcome::Queued(id) = client.submit(&req).unwrap() else {
        panic!("wait=false submit must queue");
    };
    // Let the job finish server-side while the registry is disarmed, so
    // the single torn write lands on the claiming GET below.
    wait_for(Duration::from_secs(60), "job completion", || {
        client.metrics().unwrap().get("completed").unwrap().as_usize().unwrap() >= 1
    });
    faults::arm("http.write=partial_write:1@1.0").unwrap();
    // First claim: the response is torn mid-flight, the server re-parks
    // the result, and the client's policy-driven GET retry claims the
    // re-parked copy in full on a fresh connection.
    let wire = loop {
        match client.wait_timeout(id, 5.0) {
            Ok(WaitOutcome::Done(r)) => break r,
            Ok(WaitOutcome::Running) => {}
            Err(e) => panic!("torn write must be retried, not surfaced: {e}"),
        }
    };
    faults::disarm();
    let out = wire.outcome.expect("re-parked result must be intact");
    assert_eq!(out.s.len(), 3);
    server.shutdown();
}

#[test]
fn backpressure_503_carries_retry_after_and_submit_retrying_honors_it() {
    let _g = locked();
    faults::disarm();
    let server = start_server(1, |_| {});
    let mut client = Client::connect(&server.local_addr().to_string()).unwrap();
    let mut req = JobRequest::new(
        generator_input(300, 500, Distribution::Uniform, 7, None, None),
        16,
    );
    req.config = req.config.with_fixed_power(2);
    req.engine = EnginePreference::Native;
    // Saturate the capacity-1 queue.
    let mut queued = Vec::new();
    let mut saw_503 = false;
    for _ in 0..60 {
        match client.submit(&req) {
            Ok(SubmitOutcome::Queued(id)) => queued.push(id),
            Ok(SubmitOutcome::Done(_)) => panic!("wait=false submit answered with a result"),
            Err(e) => {
                assert!(format!("{e}").contains("503"), "{e}");
                saw_503 = true;
                break;
            }
        }
    }
    assert!(saw_503, "never saw 503 with queue capacity 1");
    let hint = client.last_retry_after();
    assert!(hint.is_some(), "backpressure 503 must carry Retry-After");
    assert!((1..=30).contains(&hint.unwrap()), "hint {hint:?} outside [1, 30]");
    // submit_retrying sleeps on the hint (capped by the policy) and
    // lands once the queue drains.
    client = client.with_retry(RetryPolicy {
        max_attempts: 200,
        backoff_base_ms: 25,
        backoff_max_ms: 100,
        jitter: false,
    });
    match client.submit_retrying(&req).expect("retrying submit must land") {
        SubmitOutcome::Queued(id) => queued.push(id),
        SubmitOutcome::Done(_) => panic!("wait=false submit answered with a result"),
    }
    for id in queued {
        loop {
            match client.wait(id).unwrap() {
                WaitOutcome::Done(r) => {
                    r.outcome.expect("queued job failed");
                    break;
                }
                WaitOutcome::Running => {}
            }
        }
    }
    server.shutdown();
}

#[test]
fn journaled_jobs_are_rerun_on_restart_and_the_journal_is_cleaned() {
    let _g = locked();
    faults::disarm();
    let dir = temp_dir("journal");
    // A crashed server's journal: one accepted-but-unfinished job spec,
    // written exactly as the submit path journals raw bodies.
    let mut req = JobRequest::new(
        generator_input(30, 40, Distribution::Uniform, 8, None, None),
        3,
    );
    req.engine = EnginePreference::Native;
    let body = req.to_json().to_string();
    let entry = dir.join(format!("job-{:016}.json", 42));
    std::fs::write(&entry, body.as_bytes()).unwrap();
    // A torn temp file from a crashed journal write must be discarded.
    let torn = dir.join("job-0000000000000043.json.tmp");
    std::fs::write(&torn, &body.as_bytes()[..body.len() / 2]).unwrap();

    let server = start_server(8, |scfg| scfg.journal_dir = Some(dir.clone()));
    let mut client = Client::connect(&server.local_addr().to_string()).unwrap();
    wait_for(Duration::from_secs(60), "journal replay", || {
        let m = client.metrics().unwrap();
        m.get("journal_replayed").unwrap().as_usize().unwrap() >= 1
            && m.get("completed").unwrap().as_usize().unwrap() >= 1
    });
    // The replayed job's completion removes its journal entry (and the
    // torn temp file was swept on replay).
    wait_for(Duration::from_secs(30), "journal cleanup", || !entry.exists());
    assert!(!torn.exists(), "torn journal temp file must be discarded");
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn disarmed_fail_points_inject_nothing() {
    let _g = locked();
    faults::disarm();
    let before = faults::injected_count();
    let path = temp_dir("disarmed").join("src.bin");
    let file = file_source(&path);
    let _ = factorize(
        &Streamed::with_block_rows(&file, 13).with_retry(fast_retry(4)),
        SvdConfig::paper(4).with_fixed_power(1),
        9,
    );
    assert_eq!(faults::injected_count(), before, "disarmed run injected faults");
    let _ = std::fs::remove_dir_all(path.parent().unwrap());
}
