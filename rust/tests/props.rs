//! Property-based tests over the whole stack, via the in-tree `prop`
//! mini-framework (see DESIGN.md — proptest is unavailable offline).
//!
//! Linalg invariants, algorithm identities, and coordinator state
//! machine properties (routing, accounting, backpressure).

use srsvd::coordinator::{
    router, Coordinator, EnginePreference, JobSpec, MatrixInput, ShiftSpec,
};
use srsvd::linalg::{
    fro_diff, gemm, householder_qr, jacobi_svd, matmul, qr_rank1_update, Csr, Dense, JacobiOpts,
};
use srsvd::prop::forall;
use srsvd::svd::{MatVecOps, ShiftedRsvd, SvdConfig};

fn gaussian(g: &mut srsvd::prop::Gen, m: usize, n: usize) -> Dense {
    Dense::from_fn(m, n, |_, _| g.gaussian())
}

#[test]
fn prop_matmul_rank1_equals_composition() {
    forall("matmul_rank1 == matmul - outer", 40, |g| {
        let m = g.usize_in(1, 40);
        let n = g.usize_in(1, 40);
        let p = g.usize_in(1, 12);
        let a = gaussian(g, m, n);
        let b = gaussian(g, n, p);
        let u: Vec<f64> = (0..m).map(|_| g.gaussian()).collect();
        let v: Vec<f64> = (0..p).map(|_| g.gaussian()).collect();
        let fused = gemm::matmul_rank1(&a, &b, &u, &v);
        let mut want = matmul(&a, &b);
        for i in 0..m {
            for j in 0..p {
                want[(i, j)] -= u[i] * v[j];
            }
        }
        let err = fro_diff(&fused, &want);
        if err > 1e-9 * (m * p) as f64 + 1e-12 {
            return Err(format!("{m}x{n}x{p}: err {err}"));
        }
        Ok(())
    });
}

#[test]
fn prop_qr_reconstructs_and_orthonormal() {
    forall("householder QR invariants", 30, |g| {
        let m = g.usize_in(2, 80);
        let k = g.usize_in(1, m.min(16));
        let a = gaussian(g, m, k);
        let (q, r) = householder_qr(&a);
        let resid = srsvd::linalg::qr::orthonormality_residual(&q);
        if resid > 1e-10 {
            return Err(format!("orthonormality {resid}"));
        }
        let err = fro_diff(&matmul(&q, &r), &a);
        if err > 1e-9 * m as f64 {
            return Err(format!("reconstruction {err}"));
        }
        Ok(())
    });
}

#[test]
fn prop_qr_update_matches_refactorization() {
    forall("rank-1 QR update == refactorize", 25, |g| {
        let m = g.usize_in(3, 60);
        let k = g.usize_in(1, m.min(10));
        let a = gaussian(g, m, k);
        let (q, r) = householder_qr(&a);
        let u: Vec<f64> = (0..m).map(|_| g.gaussian()).collect();
        let v: Vec<f64> = (0..k).map(|_| g.gaussian()).collect();
        let upd = qr_rank1_update(&q, &r, &u, &v);
        let mut want = a.clone();
        for i in 0..m {
            for j in 0..k {
                want[(i, j)] += u[i] * v[j];
            }
        }
        let err = fro_diff(&matmul(&upd.q, &upd.r), &want);
        if err > 1e-8 * (m as f64) {
            return Err(format!("{m}x{k}: err {err}"));
        }
        Ok(())
    });
}

#[test]
fn prop_jacobi_svd_invariants() {
    forall("jacobi SVD invariants", 25, |g| {
        let n = g.usize_in(2, 60);
        let k = g.usize_in(1, n.min(10));
        let w = gaussian(g, n, k);
        let (u, s, v) = jacobi_svd(&w, JacobiOpts::default());
        if !s.windows(2).all(|p| p[0] >= p[1] - 1e-12) || s.iter().any(|&x| x < 0.0) {
            return Err(format!("bad spectrum {s:?}"));
        }
        let rec = matmul(&u.scale_cols(&s), &v.transpose());
        let err = fro_diff(&rec, &w);
        if err > 1e-8 * (n as f64).max(1.0) {
            return Err(format!("{n}x{k}: reconstruction {err}"));
        }
        Ok(())
    });
}

#[test]
fn prop_shifted_factorization_identity() {
    // S-RSVD(X, mu) with the same seed equals S-RSVD(X - mu 1^T, 0):
    // the paper's Eq. 11 as an executable property.
    forall("implicit == explicit shift", 15, |g| {
        let m = g.usize_in(4, 30);
        let n = g.usize_in(m, 80);
        let x = Dense::from_fn(m, n, |_, _| g.uniform());
        let mu = x.row_means();
        let k = g.usize_in(1, (m / 2).max(1));
        let cfg = SvdConfig { k, oversample: k.max(2), ..Default::default() }.with_fixed_power(1);
        let seed = g.case_seed;
        let f1 = ShiftedRsvd::new(cfg)
            .factorize(&x, &mu, &mut srsvd::rng::Xoshiro256pp::seed_from_u64(seed))
            .map_err(|e| e.to_string())?;
        let xbar = x.subtract_column(&mu);
        let f2 = ShiftedRsvd::new(cfg)
            .factorize(&xbar, &vec![0.0; m], &mut srsvd::rng::Xoshiro256pp::seed_from_u64(seed))
            .map_err(|e| e.to_string())?;
        for (a, b) in f1.s.iter().zip(&f2.s) {
            if (a - b).abs() > 1e-7 * f2.s[0].max(1e-9) {
                return Err(format!("singular values diverge: {a} vs {b}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sparse_dense_paths_agree() {
    forall("sparse path == dense path", 12, |g| {
        let m = g.usize_in(5, 30);
        let n = g.usize_in(m, 80);
        let mut rng = g.derived_rng();
        let sp = Csr::random(m, n, 0.2, &mut rng, |r| r.next_uniform() + 0.1);
        let de = sp.to_dense();
        let mu = MatVecOps::row_means(&sp);
        let k = g.usize_in(1, (m / 2).max(1));
        let cfg = SvdConfig { k, oversample: k.max(2), ..Default::default() };
        let seed = g.case_seed ^ 0x5;
        let fs = ShiftedRsvd::new(cfg)
            .factorize(&sp, &mu, &mut srsvd::rng::Xoshiro256pp::seed_from_u64(seed))
            .map_err(|e| e.to_string())?;
        let fd = ShiftedRsvd::new(cfg)
            .factorize(&de, &mu, &mut srsvd::rng::Xoshiro256pp::seed_from_u64(seed))
            .map_err(|e| e.to_string())?;
        for (a, b) in fs.s.iter().zip(&fd.s) {
            if (a - b).abs() > 1e-7 * fd.s[0].max(1e-9) {
                return Err(format!("{a} vs {b}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_router_total_and_consistent() {
    let manifest = {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        srsvd::runtime::Manifest::load(&dir).ok()
    };
    forall("router totality", 50, |g| {
        let m = g.usize_in(2, 200);
        let n = g.usize_in(m, 2000);
        let k = g.usize_in(1, (m / 2).max(1));
        let pref = *g.choose(&[EnginePreference::Auto, EnginePreference::Native]);
        let spec = JobSpec {
            input: MatrixInput::Dense(Dense::zeros(m, n)),
            config: SvdConfig::paper(k),
            shift: ShiftSpec::MeanCenter,
            engine: pref,
            seed: 0,
            score: false,
        };
        let route = router::route(&spec, manifest.as_ref()).map_err(|e| e.to_string())?;
        if pref == EnginePreference::Native && route != router::Route::Native {
            return Err("native preference not honored".into());
        }
        if let router::Route::Artifact { name } = &route {
            let man = manifest.as_ref().ok_or("artifact route without manifest")?;
            let art = man.find(name).ok_or("routed to unknown artifact")?;
            if art.m != m || art.n != n || art.k != k {
                return Err(format!("mismatched artifact {name}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_coordinator_accounting_balances() {
    // For any batch of jobs (some invalid), every handle resolves and
    // failures equal the invalid count; metrics balance at the end.
    let coord = Coordinator::start_native_only(2).unwrap();
    forall("coordinator accounting", 6, |g| {
        let jobs = g.usize_in(1, 8);
        let mut bad = 0usize;
        let mut handles = Vec::new();
        for j in 0..jobs {
            let m = g.usize_in(3, 20);
            let n = g.usize_in(m, 50);
            let invalid = g.bool();
            let shift = if invalid {
                bad += 1;
                ShiftSpec::Vector(vec![0.0; m + 1]) // wrong length -> error
            } else {
                ShiftSpec::MeanCenter
            };
            let spec = JobSpec {
                input: MatrixInput::Dense(Dense::from_fn(m, n, |_, _| g.uniform())),
                config: SvdConfig { k: 2, oversample: 2, ..Default::default() },
                shift,
                engine: EnginePreference::Native,
                seed: g.case_seed ^ j as u64,
                score: false,
            };
            handles.push(coord.submit(spec).map_err(|e| e.to_string())?);
        }
        let mut failed = 0usize;
        for h in handles {
            let r = h.wait().map_err(|e| e.to_string())?;
            if r.outcome.is_err() {
                failed += 1;
            }
        }
        if failed != bad {
            return Err(format!("expected {bad} failures, saw {failed}"));
        }
        Ok(())
    });
    let m = coord.metrics();
    assert_eq!(m.submitted, m.completed);
    assert_eq!(m.queue_depth, 0);
    coord.shutdown();
}

#[test]
fn prop_pca_errors_nonnegative_and_roughly_monotone() {
    forall("PCA error monotone in k", 10, |g| {
        let m = g.usize_in(6, 30);
        let n = g.usize_in(m, 80);
        let x = Dense::from_fn(m, n, |_, _| g.uniform());
        let seed = g.case_seed;
        let mse_at = |k: usize| -> Result<f64, String> {
            let cfg = SvdConfig::paper(k).with_fixed_power(2);
            let pca = srsvd::svd::Pca::fit(
                &x,
                cfg,
                &mut srsvd::rng::Xoshiro256pp::seed_from_u64(seed),
            )
            .map_err(|e| e.to_string())?;
            Ok(pca.mse(&x))
        };
        let k1 = g.usize_in(1, (m / 3).max(1));
        let k2 = (k1 + 2).min(m / 2).max(k1);
        let e1 = mse_at(k1)?;
        let e2 = mse_at(k2)?;
        if e1 < 0.0 || e2 < 0.0 {
            return Err("negative error".into());
        }
        // Randomized noise allowance: larger k must not be much worse.
        if k2 > k1 && e2 > e1 * 1.25 + 1e-9 {
            return Err(format!("k={k1}: {e1} vs k={k2}: {e2}"));
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip() {
    use srsvd::util::json::Json;
    forall("json write/parse roundtrip", 40, |g| {
        // Generate a random JSON tree.
        fn gen_value(g: &mut srsvd::prop::Gen, depth: usize) -> Json {
            match if depth == 0 { g.usize_in(0, 3) } else { g.usize_in(0, 5) } {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => Json::Num((g.gaussian() * 100.0 * 8.0).round() / 8.0),
                3 => Json::Str(format!("s{}-\"q\"\n", g.usize_in(0, 999))),
                4 => Json::arr((0..g.usize_in(0, 4)).map(|_| gen_value(g, depth - 1))),
                _ => Json::Obj(
                    (0..g.usize_in(0, 4))
                        .map(|i| (format!("k{i}"), gen_value(g, depth - 1)))
                        .collect(),
                ),
            }
        }
        let v = gen_value(g, 3);
        let compact = Json::parse(&v.to_string()).map_err(|e| e.to_string())?;
        let pretty = Json::parse(&v.to_string_pretty()).map_err(|e| e.to_string())?;
        if compact != v || pretty != v {
            return Err(format!("roundtrip mismatch for {v:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_json_string_escape_roundtrip() {
    use srsvd::util::json::Json;
    // The wire protocol ships arbitrary user strings (paths, error
    // text); every Unicode scalar — control characters, quotes,
    // backslashes, astral-plane characters — must survive
    // render -> parse exactly, compact and pretty.
    forall("json string escape roundtrip", 60, |g| {
        let len = g.usize_in(0, 40);
        let mut s = String::new();
        for _ in 0..len {
            let c = match g.usize_in(0, 3) {
                // Printable ASCII, escape-heavy ASCII, controls, any scalar.
                0 => char::from_u32(g.usize_in(0x20, 0x7e) as u32).unwrap(),
                1 => *g.choose(&['"', '\\', '/', '\n', '\r', '\t']),
                2 => char::from_u32(g.usize_in(0x00, 0x1f) as u32).unwrap(),
                _ => loop {
                    if let Some(c) = char::from_u32(g.usize_in(0, 0x10FFFF) as u32) {
                        break c;
                    }
                },
            };
            s.push(c);
        }
        let v = Json::Str(s.clone());
        for text in [v.to_string(), v.to_string_pretty()] {
            let back = Json::parse(&text).map_err(|e| format!("{s:?}: {e}"))?;
            if back != v {
                return Err(format!("string roundtrip mismatch for {s:?} via {text:?}"));
            }
        }
        Ok(())
    });
}

/// Parse a submit body and canonicalize the resulting spec; errors
/// become property failures.
fn canon_of(text: &str) -> Result<Vec<u8>, String> {
    use srsvd::linalg::stream::StreamConfig;
    let body = srsvd::util::json::Json::parse(text).map_err(|e| e.to_string())?;
    let sub = srsvd::server::protocol::parse_submit(&body, &StreamConfig::default())
        .map_err(|e| e.to_string())?;
    srsvd::server::cache::canonical_spec_bytes(&sub.spec)
        .ok_or_else(|| format!("uncacheable spec from {text}"))
}

#[test]
fn prop_cache_key_ignores_field_order_and_block_policy() {
    // The result cache's canonical spec bytes must depend on what is
    // computed, never on how the request was spelled (wire field order)
    // or executed (block policy — results are byte-identical across
    // block sizes, so the cache may serve across them).
    forall("cache key: field order + block policy invariance", 30, |g| {
        let m = g.usize_in(2, 12);
        let n = g.usize_in(m, 24);
        let k = g.usize_in(1, (m / 2).max(1));
        let q = g.usize_in(0, 3);
        let seed = g.case_seed & 0xFFFF;
        let input = |block: usize, budget: usize| {
            format!(
                "\"input\":{{\"kind\":\"generator\",\"m\":{m},\"n\":{n},\
                 \"dist\":\"normal\",\"seed\":{seed},\"block_rows\":{block},\
                 \"budget_mb\":{budget}}}"
            )
        };
        let fields = [
            input(0, 64),
            format!("\"k\":{k}"),
            format!("\"power_iters\":{q}"),
            format!("\"seed\":{}", seed ^ 0xAB),
            "\"score\":true".to_string(),
            "\"shift\":\"mean-center\"".to_string(),
        ];
        let forward = format!("{{{}}}", fields.join(","));
        let mut rev = fields.clone();
        rev.reverse();
        let reversed = format!("{{{}}}", rev.join(","));
        let mut blocked = fields.clone();
        blocked[0] = input(g.usize_in(1, 8), g.usize_in(1, 16));
        let blocked = format!("{{{}}}", blocked.join(","));
        let a = canon_of(&forward)?;
        if a != canon_of(&reversed)? {
            return Err("field order changed the canonical bytes".into());
        }
        if a != canon_of(&blocked)? {
            return Err("block policy leaked into the canonical bytes".into());
        }
        Ok(())
    });
}

#[test]
fn prop_cache_key_separates_every_submit_knob() {
    // Conversely: any single semantic knob change must change the
    // canonical bytes, or the cache would serve a wrong result.
    forall("cache key: one knob change -> new key", 30, |g| {
        let m = g.usize_in(2, 12);
        let n = g.usize_in(m, 24);
        let k = g.usize_in(1, (m / 2).max(1));
        let seed = g.case_seed & 0xFFFF;
        let body = |dist: &str, gen_seed: u64, k: usize, q: usize, job_seed: u64, shift: &str| {
            format!(
                "{{\"input\":{{\"kind\":\"generator\",\"m\":{m},\"n\":{n},\
                 \"dist\":\"{dist}\",\"seed\":{gen_seed}}},\"k\":{k},\
                 \"power_iters\":{q},\"seed\":{job_seed},\"shift\":\"{shift}\"}}"
            )
        };
        let base = canon_of(&body("uniform", seed, k, 1, seed, "mean-center"))?;
        let perturbed = [
            body("normal", seed, k, 1, seed, "mean-center"),
            body("uniform", seed ^ 1, k, 1, seed, "mean-center"),
            body("uniform", seed, k + 1, 1, seed, "mean-center"),
            body("uniform", seed, k, 2, seed, "mean-center"),
            body("uniform", seed, k, 1, seed ^ 1, "mean-center"),
            body("uniform", seed, k, 1, seed, "none"),
        ];
        for p in &perturbed {
            if canon_of(p)? == base {
                return Err(format!("knob change not separated: {p}"));
            }
        }
        // And the hash itself separates them too (no mixing collision
        // across this family of nearby specs).
        let mut hashes: Vec<u64> =
            std::iter::once(srsvd::server::cache::content_hash(&base))
                .chain(perturbed.iter().map(|p| {
                    Ok::<u64, String>(srsvd::server::cache::content_hash(&canon_of(p)?))
                }).collect::<Result<Vec<_>, _>>()?)
                .collect();
        hashes.sort_unstable();
        hashes.dedup();
        if hashes.len() != perturbed.len() + 1 {
            return Err("hash collision among nearby specs".into());
        }
        Ok(())
    });
}

/// A canonical spec hash out of the same nearby-spec family the cache
/// properties use, so the placement properties run over realistic keys
/// rather than raw integers.
fn spec_hash_of(g: &mut srsvd::prop::Gen) -> Result<u64, String> {
    let m = g.usize_in(2, 12);
    let n = g.usize_in(m, 24);
    let k = g.usize_in(1, (m / 2).max(1));
    let q = g.usize_in(0, 3);
    let seed = g.case_seed & 0xFFFF;
    let dist = *g.choose(&["uniform", "normal", "exponential"]);
    let body = format!(
        "{{\"input\":{{\"kind\":\"generator\",\"m\":{m},\"n\":{n},\
         \"dist\":\"{dist}\",\"seed\":{seed}}},\"k\":{k},\
         \"power_iters\":{q},\"seed\":{}}}",
        seed ^ 0xAB
    );
    Ok(srsvd::server::cache::content_hash(&canon_of(&body)?))
}

#[test]
fn prop_rendezvous_placement_is_permutation_stable() {
    use srsvd::router::replica::{rendezvous_order, Replica};
    // The routing tier's cache-affinity guarantee: which replica owns a
    // spec (and the whole failover order behind it) depends only on the
    // (spec hash, address) pairs — never on how the replica list was
    // written down. Reordering `--replicas` must not cold every cache.
    forall("rendezvous placement ignores replica-list order", 40, |g| {
        let hash = spec_hash_of(g)?;
        let count = g.usize_in(2, 6);
        let addrs: Vec<String> =
            (0..count).map(|i| format!("10.0.0.{}:7878", i + 1)).collect();
        let set: Vec<Replica> =
            addrs.iter().enumerate().map(|(i, a)| Replica::new(i, a)).collect();
        // A random permutation of the same addresses (Fisher-Yates).
        let mut perm: Vec<usize> = (0..count).collect();
        for i in (1..count).rev() {
            let j = g.usize_in(0, i);
            perm.swap(i, j);
        }
        let permuted: Vec<Replica> = perm
            .iter()
            .enumerate()
            .map(|(i, &p)| Replica::new(i, &addrs[p]))
            .collect();
        let by_addr = |set: &[Replica]| -> Vec<String> {
            rendezvous_order(hash, set).into_iter().map(|i| set[i].addr.clone()).collect()
        };
        if by_addr(&set) != by_addr(&permuted) {
            return Err(format!("order {perm:?} reshuffled placement for hash {hash:#x}"));
        }
        Ok(())
    });
}

#[test]
fn prop_rendezvous_balance_within_twice_uniform() {
    use srsvd::router::replica::{rendezvous_order, Replica};
    // Sharding must actually spread load: over a large family of nearby
    // specs, no replica of four may own more than twice its uniform
    // share (deterministic under the fixed property seeds, and far
    // inside the concentration bound for a well-mixed score).
    let replicas: Vec<Replica> = (0..4)
        .map(|i| Replica::new(i, &format!("10.1.0.{}:7878", i + 1)))
        .collect();
    let mut counts = [0usize; 4];
    let mut total = 0usize;
    forall("rendezvous balance over the spec family", 240, |g| {
        let owner = rendezvous_order(spec_hash_of(g)?, &replicas)[0];
        counts[owner] += 1;
        total += 1;
        Ok(())
    });
    assert_eq!(total, 240);
    for (i, &c) in counts.iter().enumerate() {
        assert!(c > 0, "replica {i} owns nothing out of {total} specs");
        assert!(
            c * 4 <= total * 2,
            "replica {i} owns {c}/{total} specs — more than twice the uniform share"
        );
    }
}

#[test]
fn prop_json_number_roundtrip_bitexact() {
    use srsvd::util::json::Json;
    // Factors travel over HTTP as JSON numbers; the server's
    // byte-identical contract needs render -> parse to reproduce the
    // exact f64 bits for every finite double (Rust's shortest-repr
    // Display + correctly-rounded parse; -0.0 renders as "-0" and
    // non-finite values as null — pinned by unit tests in json.rs).
    forall("json number roundtrip bitexact", 200, |g| {
        let mag = 10f64.powi(g.usize_in(0, 600) as i32 - 300);
        let mut x = g.gaussian() * mag;
        if g.bool() {
            x = -x; // exercise both signs, including the -0.0 region
        }
        if !x.is_finite() {
            return Ok(());
        }
        let v = Json::Num(x);
        let back = Json::parse(&v.to_string()).map_err(|e| format!("{x:?}: {e}"))?;
        let y = back.as_f64().map_err(|e| e.to_string())?;
        if y.to_bits() != x.to_bits() {
            return Err(format!("{x:?} ({:#x}) -> {y:?} ({:#x})", x.to_bits(), y.to_bits()));
        }
        Ok(())
    });
}
