//! Integration tests: the full service path — coordinator → router →
//! (PJRT artifact engine | native engine) — on real AOT artifacts.
//!
//! These tests exercise the exact production flow: rust generates the
//! data and Ω, the compiled HLO (pallas kernels + pure-jax QR/Jacobi)
//! factorizes, and the native engine cross-checks the numbers.

use std::path::{Path, PathBuf};

use srsvd::coordinator::{
    Coordinator, CoordinatorConfig, EnginePreference, JobSpec, MatrixInput, ShiftSpec,
};
use srsvd::linalg::Dense;
use srsvd::rng::{Rng, Xoshiro256pp};
use srsvd::runtime::Executor;
use srsvd::svd::{deterministic, SvdConfig, SvdEngine};

fn artifacts_dir() -> Option<PathBuf> {
    if !cfg!(feature = "pjrt") {
        // Default build ships the stub Executor (no `xla` crate): the
        // artifact engine is unavailable even when artifacts exist.
        eprintln!("skipping: built without the `pjrt` feature");
        return None;
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn uniform(m: usize, n: usize, seed: u64) -> Dense {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    Dense::from_fn(m, n, |_, _| rng.next_uniform())
}

/// The headline integration check: an AOT srsvd artifact produces a
/// factorization whose reconstruction error is near the deterministic
/// optimum and whose in-graph MSE agrees with a rust-side recompute.
#[test]
fn artifact_pipeline_accuracy_100x1000() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut ex = Executor::new(&dir).unwrap();
    let spec = ex.manifest().find_srsvd(100, 1000, 10, 0).unwrap().clone();

    let x = uniform(100, 1000, 1);
    let mu = x.row_means();
    let mut rng = Xoshiro256pp::seed_from_u64(2);
    let omega = Dense::gaussian(1000, spec.kk, &mut rng);

    let out = ex.run_srsvd(&spec, &x, &mu, &omega).unwrap();
    let fact = &out.factorization;
    assert_eq!(fact.u.shape(), (100, 10));
    assert_eq!(fact.v.shape(), (1000, 10));
    assert!(fact.s.windows(2).all(|w| w[0] >= w[1] - 1e-5));

    // MSE reported by the in-graph pallas scorer vs rust recompute.
    let xbar = x.subtract_column(&mu);
    let rust_mse = fact.mse_against(&xbar);
    assert!(
        (out.mse - rust_mse).abs() < 1e-3 * rust_mse.max(1.0),
        "graph mse {} vs rust {}",
        out.mse,
        rust_mse
    );

    // Near-optimal reconstruction (q=0 randomized bound is loose; the
    // centered uniform matrix has a benign spectrum).
    let opt = deterministic::optimal_mse(&xbar, 10);
    assert!(out.mse < 2.5 * opt, "mse {} vs optimal {}", out.mse, opt);
}

/// Artifact engine and native engine must agree closely when fed the
/// same Ω (identical algorithm, f32 vs f64 arithmetic).
#[test]
fn artifact_matches_native_engine_same_omega() {
    let Some(dir) = artifacts_dir() else { return };
    let mut ex = Executor::new(&dir).unwrap();
    let spec = ex.manifest().find_srsvd(100, 1000, 10, 1).unwrap().clone();

    let x = uniform(100, 1000, 3);
    let mu = x.row_means();
    let mut rng = Xoshiro256pp::seed_from_u64(4);
    let omega = Dense::gaussian(1000, spec.kk, &mut rng);

    let art = ex.run_srsvd(&spec, &x, &mu, &omega).unwrap();

    // Native run with the SAME omega: replicate by seeding identically.
    let mut rng2 = Xoshiro256pp::seed_from_u64(4);
    let cfg = SvdConfig::paper(10).with_fixed_power(1);
    let nat = srsvd::svd::ShiftedRsvd::new(cfg)
        .factorize(&x, &mu, &mut rng2)
        .unwrap();

    for (a, b) in art.factorization.s.iter().zip(&nat.s) {
        assert!(
            (a - b).abs() < 1e-3 * nat.s[0],
            "singular values diverge: {a} vs {b}"
        );
    }
    let xbar = x.subtract_column(&mu);
    let mse_a = art.factorization.mse_against(&xbar);
    let mse_n = nat.mse_against(&xbar);
    assert!((mse_a - mse_n).abs() < 5e-3 * mse_n.max(1e-9), "{mse_a} vs {mse_n}");
}

/// Full coordinator path with the artifact engine on.
#[test]
fn coordinator_routes_grid_jobs_to_artifact() {
    let Some(dir) = artifacts_dir() else { return };
    let coord = Coordinator::start(CoordinatorConfig {
        native_workers: 1,
        queue_capacity: 16,
        artifact_dir: Some(dir),
        pool_threads: None,
        io_threads: None,
        ..Default::default()
    })
    .unwrap();

    // Grid-shaped job → artifact engine.
    let spec = JobSpec::pca(MatrixInput::Dense(uniform(100, 1000, 5)), 10, 6);
    let r = coord.submit_blocking(spec).unwrap();
    assert_eq!(r.engine, SvdEngine::Artifact);
    let out = r.outcome.unwrap();
    assert_eq!(out.factorization.rank(), 10);
    assert!(out.mse.unwrap() > 0.0);

    // Off-grid job → native fallback.
    let spec = JobSpec::pca(MatrixInput::Dense(uniform(37, 91, 7)), 4, 8);
    let r = coord.submit_blocking(spec).unwrap();
    assert_eq!(r.engine, SvdEngine::Native);
    assert!(r.outcome.is_ok());

    let m = coord.metrics();
    assert_eq!(m.artifact_jobs, 1);
    assert_eq!(m.native_jobs, 1);
    assert_eq!(m.completed, 2);
    coord.shutdown();
}

/// Determinism across engines: same seed → same Ω → consistent result
/// (modulo f32), a property the paper's fig. 1d protocol relies on.
#[test]
fn coordinator_engines_agree_for_same_seed() {
    let Some(dir) = artifacts_dir() else { return };
    let coord = Coordinator::start(CoordinatorConfig {
        native_workers: 1,
        queue_capacity: 16,
        artifact_dir: Some(dir),
        pool_threads: None,
        io_threads: None,
        ..Default::default()
    })
    .unwrap();
    let x = uniform(100, 1000, 9);

    let mut art_spec = JobSpec::pca(MatrixInput::Dense(x.clone()), 10, 11);
    art_spec.engine = EnginePreference::ArtifactOnly;
    let mut nat_spec = JobSpec::pca(MatrixInput::Dense(x), 10, 11);
    nat_spec.engine = EnginePreference::Native;

    let ra = coord.submit_blocking(art_spec).unwrap().outcome.unwrap();
    let rn = coord.submit_blocking(nat_spec).unwrap().outcome.unwrap();
    let (ma, mn) = (ra.mse.unwrap(), rn.mse.unwrap());
    assert!((ma - mn).abs() < 5e-3 * mn.max(1e-9), "artifact {ma} vs native {mn}");
    coord.shutdown();
}

/// Sparse job through the full coordinator: must stay native and never
/// densify (we can't observe allocation here, but the engine choice and
/// the result are the contract).
#[test]
fn coordinator_sparse_word_job() {
    let Some(dir) = artifacts_dir() else { return };
    let coord = Coordinator::start(CoordinatorConfig {
        native_workers: 1,
        queue_capacity: 4,
        artifact_dir: Some(dir),
        pool_threads: None,
        io_threads: None,
        ..Default::default()
    })
    .unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(13);
    let spec = srsvd::data::CorpusSpec {
        contexts: 100,
        targets: 800,
        pairs: 40_000,
        zipf_s: 1.05,
        topics: 8,
    };
    let x = srsvd::data::cooccurrence_matrix(spec, &mut rng);
    let job = JobSpec {
        input: MatrixInput::Sparse(x),
        config: SvdConfig::paper(16),
        shift: ShiftSpec::MeanCenter,
        engine: EnginePreference::Auto,
        seed: 14,
        score: true,
    };
    let r = coord.submit_blocking(job).unwrap();
    assert_eq!(r.engine, SvdEngine::Native);
    let out = r.outcome.unwrap();
    assert!(out.mse.unwrap() >= 0.0);
    assert_eq!(out.factorization.rank(), 16);
    coord.shutdown();
}

/// The words-shaped artifact (1000×4000, k=64, gram-route small SVD):
/// exercises the K×K Gram eigendecomposition path of the AOT pipeline
/// on the rust runtime and cross-checks against the native gram engine.
#[test]
fn words_artifact_gram_route_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let mut ex = Executor::new(&dir).unwrap();
    let Some(spec) = ex.manifest().find_srsvd(1000, 4000, 64, 0).cloned() else {
        eprintln!("skipping: words artifact not in grid");
        return;
    };
    // Dense snapshot of a sparse-like matrix (the artifact takes dense
    // f32; the sparse path itself is native-only by design).
    let mut rng = Xoshiro256pp::seed_from_u64(21);
    let x = Dense::from_fn(1000, 4000, |_, _| {
        if rng.next_uniform() < 0.02 { rng.next_uniform() } else { 0.0 }
    });
    let mu = x.row_means();
    let mut orng = Xoshiro256pp::seed_from_u64(22);
    let omega = Dense::gaussian(4000, spec.kk, &mut orng);
    let art = ex.run_srsvd(&spec, &x, &mu, &omega).unwrap();

    let cfg = SvdConfig {
        k: 64,
        oversample: 64,
        small_svd: srsvd::svd::SmallSvdMethod::GramEig,
        ..Default::default()
    };
    let mut nrng = Xoshiro256pp::seed_from_u64(22);
    let nat = srsvd::svd::ShiftedRsvd::new(cfg)
        .factorize(&x, &mu, &mut nrng)
        .unwrap();
    // Top singular values agree (f32 graph vs f64 native, same Ω).
    for (i, (a, b)) in art.factorization.s.iter().zip(&nat.s).enumerate().take(16) {
        assert!(
            (a - b).abs() < 2e-3 * nat.s[0],
            "sv[{i}]: artifact {a} vs native {b}"
        );
    }
    let xbar = x.subtract_column(&mu);
    let (ma, mn) = (art.factorization.mse_against(&xbar), nat.mse_against(&xbar));
    assert!((ma - mn).abs() < 1e-2 * mn.max(1e-9), "{ma} vs {mn}");
}

/// Mixed burst: interleaved artifact/native jobs all complete under a
/// bounded queue.
#[test]
fn coordinator_mixed_burst() {
    let Some(dir) = artifacts_dir() else { return };
    let coord = Coordinator::start(CoordinatorConfig {
        native_workers: 2,
        queue_capacity: 8,
        artifact_dir: Some(dir),
        pool_threads: None,
        io_threads: None,
        ..Default::default()
    })
    .unwrap();
    let mut handles = Vec::new();
    for s in 0..6 {
        // Alternate grid (artifact) and off-grid (native) shapes.
        let (m, n, k) = if s % 2 == 0 { (100, 1000, 10) } else { (48, 160, 6) };
        handles.push(
            coord
                .submit(JobSpec::pca(MatrixInput::Dense(uniform(m, n, s)), k, s))
                .unwrap(),
        );
    }
    let mut art = 0;
    for h in handles {
        let r = h.wait().unwrap();
        assert!(r.outcome.is_ok());
        if r.engine == SvdEngine::Artifact {
            art += 1;
        }
    }
    assert_eq!(art, 3);
    assert_eq!(coord.metrics().completed, 6);
    coord.shutdown();
}
