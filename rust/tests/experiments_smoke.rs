//! Smoke tests for every experiment runner: each figure/table runner
//! must execute at reduced scale and reproduce the paper's *qualitative*
//! claim (who wins, direction of trends). The full-scale numbers live in
//! the benches and EXPERIMENTS.md.

use srsvd::experiments::{efficiency, fig1, table1};

#[test]
fn fig1a_gap_shrinks_with_k() {
    let rows = fig1::fig1a(&[1, 10, 50], 42);
    // S-RSVD wins at every k.
    for &(k, s, r) in &rows {
        assert!(s <= r * 1.001, "k={k}: {s} vs {r}");
    }
    // And the relative gap shrinks as k grows.
    let gap = |i: usize| rows[i].2 / rows[i].1;
    assert!(gap(0) > gap(2), "gap(k=1)={} gap(k=50)={}", gap(0), gap(2));
}

#[test]
fn fig1b_srsvd_wins_at_every_sample_size() {
    for (n, s, r) in fig1::fig1b(&[200, 800], &[1, 3, 8, 20], 42) {
        assert!(s < r, "n={n}: {s} vs {r}");
    }
}

#[test]
fn fig1c_srsvd_wins_for_every_distribution() {
    for (dist, s, r) in fig1::fig1c(&[1, 3, 8, 20], 42) {
        assert!(s < r, "{dist}: {s} vs {r}");
    }
}

#[test]
fn fig1d_implicit_explicit_identical() {
    for (k, i, e) in fig1::fig1d(&[1, 4, 16], 42) {
        assert!((i - e).abs() < 1e-9 * e.max(1.0), "k={k}: {i} vs {e}");
    }
}

#[test]
fn fig1e_power_iteration_narrows_gap() {
    let ks = [1, 3, 8, 20];
    let rows = fig1::fig1e(&[0, 2], &ks, 42);
    let gap_q0 = rows[0].2 - rows[0].1; // rsvd - srsvd at q=0
    let gap_q2 = rows[1].2 - rows[1].1;
    assert!(gap_q0 > 0.0, "srsvd must win at q=0");
    assert!(gap_q2 < gap_q0, "power iteration should narrow the gap");
    assert!(gap_q2 > -1e-9, "srsvd should not lose at q=2: {gap_q2}");
}

#[test]
fn fig1f_never_positive() {
    for (dist, series) in fig1::fig1f(&[0, 1], &[1, 3, 8], 42) {
        for (q, d) in series {
            assert!(d < 1e-9, "{dist} q={q}: diff {d} > 0");
        }
    }
}

#[test]
fn table1_images_reproduce_winners() {
    let digits = table1::digits_stats(300, 5, 42);
    assert!(digits.mse_srsvd < digits.mse_rsvd);
    assert!(digits.p2 < 0.05);
    let faces = table1::faces_stats(
        srsvd::data::FacesSpec { side: 16, count: 100, rank: 10, noise: 5.0 },
        5,
        42,
    );
    assert!(faces.mse_srsvd < faces.mse_rsvd);
    assert!(faces.wr_srsvd > 0.6, "faces wr {}", faces.wr_srsvd);
}

#[test]
fn table1_words_reproduce_winner() {
    let s = table1::words_stats(600, 50_000, 24, 4, 42);
    assert!(s.mse_srsvd < s.mse_rsvd, "{s:?}");
    assert!(s.wr_srsvd >= 0.5, "{s:?}");
}

#[test]
fn efficiency_sparse_beats_densified() {
    // Strict monotonic growth in n is asserted only at bench scale
    // (single-shot timings at this size are too noisy); here we check
    // the headline inequality holds with margin at both points.
    let rows = efficiency::sweep(150, &[(1000, 0.01), (6000, 0.004)], 6, 42);
    for r in &rows {
        assert!(r.speedup() > 1.5, "sparse path should win clearly: {r:?}");
    }
}
