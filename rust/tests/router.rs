//! Routing-tier integration tests: real loopback replicas (coordinator
//! + HTTP server each) behind a real [`Router`].
//!
//! Pinned contracts:
//! - Spec-hash affinity: an identical cacheable spec always lands on
//!   the same replica, and its warm replay through the router is
//!   byte-identical to the cold response (`native_jobs` stays flat,
//!   `cache_hits` ticks — on the owner only).
//! - Failover: killing the owning replica never fails a client submit;
//!   the router moves to the next rendezvous candidate and counts a
//!   `failovers`.
//! - Health loop: `unhealthy_after` consecutive probe failures mark a
//!   replica down, one success re-admits it. Probe rounds are driven by
//!   hand (`Router::probe_now` under a pinned fake [`Clock`]) — no
//!   test sleeps.
//! - Routed ids: `DELETE`/blocking `GET` follow the replica tag in the
//!   router-issued id; a replica's `404` surfaces as the typed
//!   `Error::NotFound` straight through the router.
//! - `GET /readyz` on a replica answers `503` once the bounded job
//!   queue is at capacity.

use std::net::TcpListener;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use srsvd::coordinator::{Coordinator, CoordinatorConfig, EnginePreference};
use srsvd::data::Distribution;
use srsvd::linalg::stream::StreamConfig;
use srsvd::router::{Router, RouterConfig};
use srsvd::server::client::SubmitOutcome;
use srsvd::server::protocol::{generator_input, JobRequest};
use srsvd::server::{Client, Clock, Server, ServerConfig};
use srsvd::util::json::Json;
use srsvd::util::Error;

fn coordinator(native_workers: usize) -> Arc<Coordinator> {
    Arc::new(
        Coordinator::start(CoordinatorConfig {
            native_workers,
            queue_capacity: 16,
            artifact_dir: None,
            pool_threads: Some(2),
            io_threads: None,
            ..Default::default()
        })
        .unwrap(),
    )
}

fn server_config() -> ServerConfig {
    ServerConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        ..Default::default()
    }
}

/// One live replica: a coordinator plus its HTTP server on a free
/// loopback port.
fn replica(native_workers: usize) -> (Arc<Coordinator>, Server, String) {
    let coord = coordinator(native_workers);
    let server =
        Server::bind(Arc::clone(&coord), &server_config(), StreamConfig::default()).unwrap();
    let addr = server.local_addr().to_string();
    (coord, server, addr)
}

fn router_over(replicas: Vec<String>) -> Router {
    let cfg = RouterConfig {
        listen: "127.0.0.1:0".into(),
        replicas,
        workers: 2,
        ..Default::default()
    };
    Router::bind(&cfg, StreamConfig::default()).unwrap()
}

fn client_for(addr: &str) -> Client {
    Client::connect(addr).unwrap()
}

/// A flat counter out of a replica's `/metrics`.
fn counter(client: &mut Client, key: &str) -> u64 {
    client.metrics().unwrap().get(key).unwrap().as_u64().unwrap()
}

/// A counter out of the `"router"` object of the router's `/metrics`.
fn router_counter(client: &mut Client, key: &str) -> u64 {
    client.metrics().unwrap().get("router").unwrap().get(key).unwrap().as_u64().unwrap()
}

/// A waited, cacheable (generator-input) submit body. Identical seeds
/// give byte-identical request bodies, hence one canonical spec hash.
fn cacheable_body(gen_seed: u64) -> String {
    let mut req = JobRequest::new(
        generator_input(40, 120, Distribution::Uniform, gen_seed, None, None),
        6,
    );
    req.engine = EnginePreference::Native;
    req.seed = 3;
    req.wait = true;
    req.to_json().to_string()
}

/// A job slow enough that follow-up requests land while it occupies
/// the single native worker (same shape as the lifecycle suite's).
fn blocker_request() -> JobRequest {
    let mut req = JobRequest::new(
        generator_input(300, 500, Distribution::Uniform, 4, None, None),
        16,
    );
    req.config = req.config.with_fixed_power(2);
    req.engine = EnginePreference::Native;
    req
}

/// A small job that queues behind the blocker.
fn victim_request(seed: u64) -> JobRequest {
    let mut req =
        JobRequest::new(generator_input(8, 24, Distribution::Uniform, seed, None, None), 2);
    req.engine = EnginePreference::Native;
    req
}

#[test]
fn spec_hash_affinity_replays_cached_bytes_through_the_router() {
    let (_coord_a, server_a, addr_a) = replica(2);
    let (_coord_b, server_b, addr_b) = replica(2);
    let router = router_over(vec![addr_a.clone(), addr_b.clone()]);
    let mut rc = client_for(&router.local_addr().to_string());

    rc.health().unwrap();
    let body = cacheable_body(9);
    let (status, cold) = rc.request_raw("POST", "/v1/jobs", Some(body.as_bytes())).unwrap();
    assert_eq!(status, 200, "cold waited submit through the router must answer the result");

    // Exactly one replica owns the spec under rendezvous placement.
    let mut cl_a = client_for(&addr_a);
    let mut cl_b = client_for(&addr_b);
    let cold_a = counter(&mut cl_a, "native_jobs");
    let cold_b = counter(&mut cl_b, "native_jobs");
    assert_eq!(cold_a + cold_b, 1, "exactly one replica may run the cold job");

    let (status, warm) = rc.request_raw("POST", "/v1/jobs", Some(body.as_bytes())).unwrap();
    assert_eq!(status, 200, "warm waited submit must answer the result");
    assert_eq!(warm, cold, "the cache hit must replay the cold bytes through the router");

    // The warm submit landed on the same replica and hit its cache:
    // neither coordinator ran a second job.
    assert_eq!(counter(&mut cl_a, "native_jobs"), cold_a, "warm submit must not rerun");
    assert_eq!(counter(&mut cl_b, "native_jobs"), cold_b, "warm submit must not change owners");
    let hits = counter(&mut cl_a, "cache_hits") + counter(&mut cl_b, "cache_hits");
    assert!(hits >= 1, "the warm submit must hit the owner's result cache");

    // The aggregated router metrics carry both counters and snapshots.
    assert!(router_counter(&mut rc, "routed") >= 2, "both submits must count as routed");
    let m = rc.metrics().unwrap();
    let Json::Arr(reps) = m.get("replicas").unwrap() else {
        panic!("router metrics must carry a replicas array");
    };
    assert_eq!(reps.len(), 2, "one snapshot entry per replica");
    for entry in reps {
        assert_eq!(entry.get("healthy").unwrap(), &Json::Bool(true));
    }

    router.shutdown();
    server_a.shutdown();
    server_b.shutdown();
}

#[test]
fn killed_owner_fails_over_without_a_failed_client_request() {
    let (_coord_a, server_a, addr_a) = replica(2);
    let (_coord_b, server_b, addr_b) = replica(2);
    let router = router_over(vec![addr_a.clone(), addr_b.clone()]);
    let mut rc = client_for(&router.local_addr().to_string());

    let body = cacheable_body(21);
    let (status, _) = rc.request_raw("POST", "/v1/jobs", Some(body.as_bytes())).unwrap();
    assert_eq!(status, 200, "cold submit must succeed");

    // Find the rendezvous owner of this spec, then kill its server.
    let mut cl_a = client_for(&addr_a);
    let mut cl_b = client_for(&addr_b);
    let a_owns = counter(&mut cl_a, "native_jobs") == 1;
    let mut survivor_cl = if a_owns { cl_b } else { cl_a };
    let survivor_jobs = counter(&mut survivor_cl, "native_jobs");
    assert_eq!(survivor_jobs, 0, "the survivor must not have run the cold job");
    let mut servers = [Some(server_a), Some(server_b)];
    let owner = if a_owns { 0 } else { 1 };
    servers[owner].take().unwrap().shutdown();

    // The identical spec now rendezvouses at the dead owner first; the
    // submit must still succeed, transparently, on the survivor.
    let (status, bytes) = rc.request_raw("POST", "/v1/jobs", Some(body.as_bytes())).unwrap();
    assert_eq!(status, 200, "failover submit must succeed without a client-visible error");
    let parsed = Json::parse(std::str::from_utf8(&bytes).unwrap()).unwrap();
    assert_eq!(parsed.get("ok").unwrap(), &Json::Bool(true));

    // The survivor ran it natively (its cache was cold for this spec),
    // and the router counted the move past the dead owner.
    assert_eq!(counter(&mut survivor_cl, "native_jobs"), survivor_jobs + 1);
    assert!(router_counter(&mut rc, "failovers") >= 1, "the failover must be counted");

    router.shutdown();
    for s in &mut servers {
        if let Some(s) = s.take() {
            s.shutdown();
        }
    }
}

/// Hand-advanced [`Clock`]: `now_ms` is whatever the test last stored.
/// Pinned at zero it parks the router's background probe loop, so
/// every probe round below is one explicit `probe_now` call.
struct FakeClock(AtomicU64);

impl Clock for FakeClock {
    fn now_ms(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[test]
fn health_loop_marks_down_and_readmits_without_sleeping() {
    // Reserve a loopback port with nothing listening behind it: bind,
    // read the port, drop the listener.
    let reserved = TcpListener::bind("127.0.0.1:0").unwrap();
    let dead_addr = reserved.local_addr().unwrap().to_string();
    drop(reserved);

    let cfg = RouterConfig {
        listen: "127.0.0.1:0".into(),
        replicas: vec![dead_addr.clone()],
        workers: 2,
        // Far-future interval + a clock pinned at zero: the background
        // loop never fires on its own.
        probe_interval_ms: u64::MAX / 4,
        unhealthy_after: 3,
        ..Default::default()
    };
    let clock = Arc::new(FakeClock(AtomicU64::new(0)));
    let router =
        Router::bind_with_clock(&cfg, StreamConfig::default(), clock as Arc<dyn Clock>).unwrap();
    let mut rc = client_for(&router.local_addr().to_string());

    // Replicas start healthy: the router must route before round one.
    assert_eq!(router_counter(&mut rc, "replicas_healthy"), 1);

    // Two failing rounds stay below the threshold; the third flips.
    router.probe_now();
    router.probe_now();
    assert_eq!(router_counter(&mut rc, "replicas_healthy"), 1, "two failures may not mark down");
    router.probe_now();
    assert_eq!(router_counter(&mut rc, "replicas_healthy"), 0, "third failure must mark down");
    assert!(router_counter(&mut rc, "probe_failures") >= 3);
    let (status, _) = rc.request("GET", "/readyz", None).unwrap();
    assert_eq!(status, 503, "a router with no healthy replicas must fail readiness");

    // Bring a real replica up on the exact address being probed.
    let coord = coordinator(1);
    let scfg = ServerConfig { addr: dead_addr, workers: 2, ..Default::default() };
    let server = Server::bind(Arc::clone(&coord), &scfg, StreamConfig::default()).unwrap();

    // One successful round re-admits it...
    router.probe_now();
    assert_eq!(router_counter(&mut rc, "replicas_healthy"), 1, "one success must re-admit");
    let (status, _) = rc.request("GET", "/readyz", None).unwrap();
    assert_eq!(status, 200);

    // ...and submits reach it again.
    let body = cacheable_body(5);
    let (status, _) = rc.request_raw("POST", "/v1/jobs", Some(body.as_bytes())).unwrap();
    assert_eq!(status, 200, "a re-admitted replica must take traffic");

    router.shutdown();
    server.shutdown();
}

#[test]
fn routed_cancel_round_trips_and_maps_unknown_ids() {
    let (_coord, server, addr) = replica(1);
    let router = router_over(vec![addr]);
    let mut rc = client_for(&router.local_addr().to_string());

    // Occupy the only native worker, then queue the victim — both
    // through the router, which re-tags the 202 ids.
    let SubmitOutcome::Queued(blocker) = rc.submit(&blocker_request()).unwrap() else {
        panic!("wait=false submit must queue");
    };
    let SubmitOutcome::Queued(victim) = rc.submit(&victim_request(7)).unwrap() else {
        panic!("wait=false submit must queue");
    };
    // Router-issued ids carry the replica tag in the low bits.
    assert_eq!(victim & 0xff, 0, "single-replica set: the tag must be index 0");
    assert!(victim >> 8 >= 1, "the upstream id must survive the tag shift");
    assert_ne!(blocker, victim);

    // Cancel routes by the tag; the claiming GET sees 410 Gone; the
    // 410 was a delivery, so a re-cancel answers 409.
    assert!(rc.cancel(victim).unwrap(), "routed cancel of a pending job must answer 200");
    let err = rc.wait(victim).unwrap_err();
    let text = format!("{err}");
    assert!(text.contains("410"), "cancelled claim must be 410 through the router, got: {text}");
    assert!(!rc.cancel(victim).unwrap(), "re-cancel after delivery must answer 409");

    // Unknown id, valid tag: the replica's 404 surfaces as the typed
    // NotFound straight through the router.
    match rc.cancel(123_456 << 8) {
        Err(Error::NotFound(m)) => assert!(m.contains("404"), "got: {m}"),
        other => panic!("unknown routed id must be NotFound, got {other:?}"),
    }
    // A tag beyond the replica set is the router's own 404, and a
    // malformed id never leaves the router either.
    let (status, _) = rc.request("DELETE", "/v1/jobs/51", None).unwrap();
    assert_eq!(status, 404, "an out-of-range replica tag must 404 at the router");
    let (status, _) = rc.request("DELETE", "/v1/jobs/not-a-number", None).unwrap();
    assert_eq!(status, 400, "a malformed id must 400 at the router");

    router.shutdown();
    server.shutdown();
}

#[test]
fn server_readyz_answers_503_at_queue_capacity() {
    let coord = Arc::new(
        Coordinator::start(CoordinatorConfig {
            native_workers: 1,
            queue_capacity: 1,
            artifact_dir: None,
            pool_threads: Some(2),
            io_threads: None,
            ..Default::default()
        })
        .unwrap(),
    );
    let server =
        Server::bind(Arc::clone(&coord), &server_config(), StreamConfig::default()).unwrap();
    let mut client = client_for(&server.local_addr().to_string());

    let (status, body) = client.request("GET", "/readyz", None).unwrap();
    assert_eq!(status, 200, "an idle queue must be ready");
    assert_eq!(body.get("status").unwrap(), &Json::str("ready"));

    // Fill the worker with the blocker, then the only queue slot with
    // the victim — retrying past 503s until the worker has picked the
    // blocker up and the slot is free.
    let SubmitOutcome::Queued(_blocker) = client.submit(&blocker_request()).unwrap() else {
        panic!("wait=false submit must queue");
    };
    loop {
        match client.submit(&victim_request(5)) {
            Ok(SubmitOutcome::Queued(_)) => break,
            Ok(other) => panic!("victim must queue, got {other:?}"),
            Err(e) => {
                let text = format!("{e}");
                assert!(text.contains("503"), "only queue-full may reject the victim: {text}");
            }
        }
    }

    // The victim occupies the whole capacity-1 queue while the blocker
    // runs: readiness must now fail, deterministically.
    let (status, body) = client.request("GET", "/readyz", None).unwrap();
    assert_eq!(status, 503, "a full queue must fail readiness");
    assert_eq!(body.get("status").unwrap(), &Json::str("saturated"));
    assert_eq!(body.get("queue_capacity").unwrap().as_u64().unwrap(), 1);

    server.shutdown();
}
