//! Integration tests for the network service layer: a real
//! `TcpListener` on a loopback port, the std-only blocking client, and
//! the full request lifecycle against a live coordinator.
//!
//! The headline contract: a factorization submitted over HTTP is
//! **byte-identical** to the same `JobSpec` submitted in-process — for
//! dense payloads and for streamed (generator / server-side file)
//! inputs — because the wire protocol round-trips every `f64` exactly.
//! Also pinned: queue saturation yields `503` (never a hang or panic),
//! malformed requests yield `400` (never a panic), and graceful
//! shutdown drains in-flight requests.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use srsvd::coordinator::{
    Coordinator, CoordinatorConfig, EnginePreference, JobSpec, MatrixInput, ShiftSpec,
};
use srsvd::data::Distribution;
use srsvd::linalg::stream::{spill_to_file, FileSource, GeneratorSource, StreamConfig};
use srsvd::linalg::Dense;
use srsvd::rng::{Rng, Xoshiro256pp};
use srsvd::server::client::{SubmitOutcome, WaitOutcome};
use srsvd::server::protocol::{
    dense_input, file_input, generator_input, JobRequest, WireOutput,
};
use srsvd::server::{Client, Server, ServerConfig};
use srsvd::svd::{Factorization, PassPolicy, SvdConfig};

fn start_service(
    native_workers: usize,
    queue_capacity: usize,
    http_workers: usize,
) -> (Arc<Coordinator>, Server) {
    let coord = Arc::new(
        Coordinator::start(CoordinatorConfig {
            native_workers,
            queue_capacity,
            artifact_dir: None,
            pool_threads: Some(2),
            io_threads: None,
            ..Default::default()
        })
        .unwrap(),
    );
    let server = Server::bind(
        Arc::clone(&coord),
        &ServerConfig {
            addr: "127.0.0.1:0".into(),
            max_body_bytes: 64 << 20,
            workers: http_workers,
            request_timeout_s: 30,
            ..Default::default()
        },
        StreamConfig::default(),
    )
    .unwrap();
    (coord, server)
}

fn client_for(server: &Server) -> Client {
    Client::connect(&server.local_addr().to_string()).unwrap()
}

/// u/s/v (and MSE) byte-equality between a wire result and an
/// in-process factorization.
fn assert_identical(wire: &WireOutput, local: &Factorization, local_mse: Option<f64>, what: &str) {
    let bits = |x: &Dense| -> Vec<u64> { x.data().iter().map(|v| v.to_bits()).collect() };
    assert_eq!(
        wire.s.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        local.s.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        "{what}: singular values diverged"
    );
    assert_eq!(bits(&wire.u), bits(&local.u), "{what}: U diverged");
    assert_eq!(bits(&wire.v), bits(&local.v), "{what}: V diverged");
    assert_eq!(
        wire.mse.map(f64::to_bits),
        local_mse.map(f64::to_bits),
        "{what}: MSE diverged"
    );
}

#[test]
fn dense_job_over_loopback_is_byte_identical_to_in_process() {
    let (coord, server) = start_service(2, 64, 2);
    let mut client = client_for(&server);
    client.health().unwrap();

    let mut rng = Xoshiro256pp::seed_from_u64(1);
    let x = Dense::from_fn(30, 80, |_, _| rng.next_uniform());

    let mut req = JobRequest::new(dense_input(&x), 4);
    req.engine = EnginePreference::Native;
    req.seed = 7;
    let wire = client.submit_wait(&req).unwrap();
    assert_eq!(wire.engine, "native");
    let wire_out = wire.outcome.expect("wire job failed");

    let local = coord
        .submit_blocking(JobSpec {
            input: MatrixInput::Dense(x),
            config: SvdConfig::paper(4),
            shift: ShiftSpec::MeanCenter,
            engine: EnginePreference::Native,
            seed: 7,
            score: true,
        })
        .unwrap()
        .outcome
        .expect("local job failed");

    assert_identical(&wire_out, &local.factorization, local.mse, "dense");
    server.shutdown();
}

#[test]
fn generator_streamed_job_over_loopback_is_byte_identical() {
    let (coord, server) = start_service(2, 64, 2);
    let mut client = client_for(&server);

    // The wire job is a seed, not a payload: the server builds the
    // GeneratorSource and sweeps it out-of-core.
    let mut req = JobRequest::new(
        generator_input(50, 40, Distribution::Uniform, 5, Some(7), None),
        3,
    );
    req.engine = EnginePreference::Native;
    req.seed = 11;
    let wire = client.submit_wait(&req).unwrap();
    let wire_out = wire.outcome.expect("wire job failed");

    let src = GeneratorSource::new(50, 40, Distribution::Uniform, 5).unwrap();
    let stream_cfg = StreamConfig { block_rows: 7, ..Default::default() };
    let local = coord
        .submit_blocking(JobSpec {
            input: MatrixInput::streamed(src, &stream_cfg),
            config: SvdConfig::paper(3),
            shift: ShiftSpec::MeanCenter,
            engine: EnginePreference::Native,
            seed: 11,
            score: true,
        })
        .unwrap()
        .outcome
        .expect("local job failed");

    assert_identical(&wire_out, &local.factorization, local.mse, "generator");
    server.shutdown();
}

#[test]
fn file_streamed_job_resolves_path_server_side() {
    let (coord, server) = start_service(2, 64, 2);
    let mut client = client_for(&server);

    let gen = GeneratorSource::new(60, 30, Distribution::Exponential, 9).unwrap();
    let path = std::env::temp_dir().join("srsvd_server_test_file_job.bin");
    spill_to_file(&gen, &path, 16).unwrap();
    let path_text = path.to_str().unwrap().to_string();

    let mut req = JobRequest::new(file_input(&path_text, None, Some(4)), 3);
    req.engine = EnginePreference::Native;
    req.seed = 13;
    let wire = client.submit_wait(&req).unwrap();
    assert_eq!(wire.engine, "native");
    let wire_out = wire.outcome.expect("wire job failed");

    let src = FileSource::open(&path).unwrap();
    let stream_cfg = StreamConfig { block_rows: 0, budget_mb: 4, prefetch: true };
    let local = coord
        .submit_blocking(JobSpec {
            input: MatrixInput::streamed(src, &stream_cfg),
            config: SvdConfig::paper(3),
            shift: ShiftSpec::MeanCenter,
            engine: EnginePreference::Native,
            seed: 13,
            score: true,
        })
        .unwrap()
        .outcome
        .expect("local job failed");

    assert_identical(&wire_out, &local.factorization, local.mse, "file");

    // A bogus server-side path is a client error, not a panic.
    let req = JobRequest::new(file_input("/definitely/not/here.bin", None, None), 2);
    let err = client.submit(&req).unwrap_err();
    assert!(format!("{err}").contains("400"), "{err}");

    server.shutdown();
    let _ = std::fs::remove_file(&path);
}

#[test]
fn fused_pass_policy_round_trips_over_the_wire() {
    let (coord, server) = start_service(2, 64, 2);
    let mut client = client_for(&server);

    let mut req = JobRequest::new(
        generator_input(60, 40, Distribution::Uniform, 2, Some(16), None),
        4,
    );
    req.config = req.config.with_fixed_power(1).with_pass_policy(PassPolicy::Fused);
    req.engine = EnginePreference::Native;
    req.seed = 21;
    let wire = client.submit_wait(&req).unwrap();
    let wire_out = wire.outcome.expect("wire job failed");

    let src = GeneratorSource::new(60, 40, Distribution::Uniform, 2).unwrap();
    let stream_cfg = StreamConfig { block_rows: 16, ..Default::default() };
    let local = coord
        .submit_blocking(JobSpec {
            input: MatrixInput::streamed(src, &stream_cfg),
            config: SvdConfig::paper(4).with_fixed_power(1).with_pass_policy(PassPolicy::Fused),
            shift: ShiftSpec::MeanCenter,
            engine: EnginePreference::Native,
            seed: 21,
            score: true,
        })
        .unwrap()
        .outcome
        .expect("local job failed");

    assert_identical(&wire_out, &local.factorization, local.mse, "fused");

    // The streamed job's I/O shows up in the service counters.
    let m = client.metrics().unwrap();
    assert!(m.get("stream_passes").unwrap().as_usize().unwrap() >= 1);
    assert!(m.get("stream_bytes_read").unwrap().as_usize().unwrap() > 0);
    server.shutdown();
}

/// A claimed result whose response write fails must be re-parked, not
/// dropped: the claiming `GET /v1/jobs/{id}` is retryable.
#[test]
fn claimed_result_surviving_failed_write_is_retryable() {
    // Short request timeout: the stalled response write below fails
    // after ~1 s instead of pinning a connection worker.
    let coord = Arc::new(
        Coordinator::start(CoordinatorConfig {
            native_workers: 1,
            queue_capacity: 16,
            artifact_dir: None,
            pool_threads: Some(2),
            io_threads: None,
            ..Default::default()
        })
        .unwrap(),
    );
    let server = Server::bind(
        Arc::clone(&coord),
        &ServerConfig {
            addr: "127.0.0.1:0".into(),
            max_body_bytes: 64 << 20,
            workers: 2,
            request_timeout_s: 1,
            ..Default::default()
        },
        StreamConfig::default(),
    )
    .unwrap();
    let mut client = client_for(&server);

    // A job whose result body (~35 MB of factor JSON: u is 120000x16)
    // cannot fit in the loopback socket buffers, so an unread response
    // write reliably stalls and then fails.
    let mut req = JobRequest::new(
        generator_input(120_000, 32, Distribution::Uniform, 1, None, None),
        16,
    );
    req.engine = EnginePreference::Native;
    req.score = false;
    let SubmitOutcome::Queued(id) = client.submit(&req).unwrap() else {
        panic!("wait=false submit must queue");
    };

    // Let the job finish server-side before claiming it.
    let deadline = std::time::Instant::now() + Duration::from_secs(120);
    loop {
        let m = client.metrics().unwrap();
        if m.get("completed").unwrap().as_usize().unwrap() >= 1 {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "job never completed");
        std::thread::sleep(Duration::from_millis(20));
    }

    // Claim the result but never read the response: the server's write
    // stalls on the full socket buffers and errors at its write
    // timeout. Pre-fix, the result was dropped here.
    {
        let mut s = TcpStream::connect(server.local_addr()).unwrap();
        s.write_all(
            format!("GET /v1/jobs/{id} HTTP/1.1\r\nhost: t\r\nconnection: close\r\n\r\n")
                .as_bytes(),
        )
        .unwrap();
        std::thread::sleep(Duration::from_secs(3));
        // Dropped with the response unread.
    }

    // The retried GET claims the re-parked result in full. A 404 here
    // (result dropped) is the regression this test pins.
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    let wire = loop {
        match client.wait_timeout(id, 0.0) {
            Ok(WaitOutcome::Done(r)) => break r,
            Ok(WaitOutcome::Running) => std::thread::sleep(Duration::from_millis(50)),
            Err(e) => {
                // 404 is expected only while the failed write is still
                // in flight; it must turn into a 200 once re-parked.
                assert!(format!("{e}").contains("404"), "{e}");
                assert!(
                    std::time::Instant::now() < deadline,
                    "claimed result was dropped, not re-parked"
                );
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    };
    let out = wire.outcome.expect("re-parked job result must be intact");
    assert_eq!(out.u.shape(), (120_000, 16));
    assert_eq!(out.s.len(), 16);
    // Once claimed successfully, the id is forgotten again.
    let err = client.wait(id).unwrap_err();
    assert!(format!("{err}").contains("404"), "{err}");
    server.shutdown();
}

#[test]
fn queue_saturation_returns_503_and_drains() {
    // 1 native worker, queue capacity 1: a burst must hit 503.
    let (_coord, server) = start_service(1, 1, 2);
    let mut client = client_for(&server);

    let mut req = JobRequest::new(
        generator_input(300, 500, Distribution::Uniform, 3, None, None),
        16,
    );
    req.config = req.config.with_fixed_power(2);
    req.engine = EnginePreference::Native;

    let mut queued = Vec::new();
    let mut saw_503 = false;
    for _ in 0..60 {
        match client.submit(&req) {
            Ok(SubmitOutcome::Queued(id)) => queued.push(id),
            Ok(SubmitOutcome::Done(_)) => panic!("wait=false submit answered with a result"),
            Err(e) => {
                let text = format!("{e}");
                assert!(text.contains("503"), "unexpected error: {text}");
                assert!(text.contains("backpressure"), "unexpected error: {text}");
                saw_503 = true;
                break;
            }
        }
    }
    assert!(saw_503, "never saw 503 with queue capacity 1");
    assert!(!queued.is_empty(), "nothing was accepted before saturation");

    // Everything accepted still completes; the service never wedges.
    for id in queued {
        loop {
            match client.wait(id).unwrap() {
                WaitOutcome::Done(r) => {
                    r.outcome.expect("queued job failed");
                    break;
                }
                WaitOutcome::Running => {}
            }
        }
    }

    let m = client.metrics().unwrap();
    assert!(m.get("http_rejected").unwrap().as_usize().unwrap() >= 1);
    assert!(m.get("http_accepted").unwrap().as_usize().unwrap() >= 1);
    server.shutdown();
}

/// Send raw bytes, read until the server closes, return the response
/// text. Only for exchanges where the server closes the connection
/// (error paths and `Connection: close` requests).
fn raw_exchange(addr: &str, payload: &[u8]) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    s.write_all(payload).unwrap();
    let mut buf = Vec::new();
    let _ = s.read_to_end(&mut buf);
    String::from_utf8_lossy(&buf).into_owned()
}

#[test]
fn malformed_requests_get_400_not_a_panic() {
    let (_coord, server) = start_service(1, 16, 2);
    let addr = server.local_addr().to_string();
    let mut client = client_for(&server);

    // Garbage request line.
    let resp = raw_exchange(&addr, b"GARBAGE\r\n\r\n");
    assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");

    // Truncated JSON body.
    let resp = raw_exchange(
        &addr,
        b"POST /v1/jobs HTTP/1.1\r\nconnection: close\r\ncontent-length: 1\r\n\r\n{",
    );
    assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");

    // Valid JSON, invalid schema.
    let resp = raw_exchange(
        &addr,
        b"POST /v1/jobs HTTP/1.1\r\nconnection: close\r\ncontent-length: 9\r\n\r\n{\"k\": 2 }",
    );
    assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");

    // Oversized body is refused up front.
    let resp = raw_exchange(
        &addr,
        b"POST /v1/jobs HTTP/1.1\r\nconnection: close\r\ncontent-length: 999999999999\r\n\r\n",
    );
    assert!(resp.starts_with("HTTP/1.1 413"), "{resp}");

    // Unknown pass_policy value: strict 400, not a silent default.
    let body = r#"{"input":{"kind":"generator","m":4,"n":4,"dist":"uniform"},"k":1,"pass_policy":"warp"}"#;
    let resp = raw_exchange(
        &addr,
        format!(
            "POST /v1/jobs HTTP/1.1\r\nconnection: close\r\ncontent-length: {}\r\n\r\n{}",
            body.len(),
            body
        )
        .as_bytes(),
    );
    assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
    assert!(resp.contains("pass_policy"), "{resp}");

    // Unknown endpoint / wrong method, via the keep-alive client.
    let (status, _) = client
        .request("GET", "/nope", None)
        .unwrap();
    assert_eq!(status, 404);
    let (status, _) = client.request("DELETE", "/metrics", None).unwrap();
    assert_eq!(status, 405);

    // After all that abuse the service still answers.
    client.health().unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(2);
    let x = Dense::from_fn(10, 20, |_, _| rng.next_uniform());
    let wire = client
        .submit_wait(&JobRequest::new(dense_input(&x), 2))
        .unwrap();
    assert!(wire.outcome.is_ok());
    server.shutdown();
}

/// `engine=artifact` submits the router must refuse come back as 400s
/// carrying the router's *specific* reason string — the client learns
/// which knob to change, not a generic "invalid job".
#[test]
fn artifact_only_refusals_surface_router_reason_as_400() {
    let (_coord, server) = start_service(1, 16, 2);
    let mut client = client_for(&server);

    // Fused pass policy is native-only.
    let mut rng = Xoshiro256pp::seed_from_u64(9);
    let x = Dense::from_fn(10, 20, |_, _| rng.next_uniform());
    let mut req = JobRequest::new(dense_input(&x), 2);
    req.engine = EnginePreference::ArtifactOnly;
    req.config = req.config.with_pass_policy(PassPolicy::Fused);
    let text = format!("{}", client.submit(&req).unwrap_err());
    assert!(text.contains("400"), "{text}");
    assert!(text.contains("pass_policy=fused"), "{text}");

    // A server-side file is a streamed input: never an artifact operand.
    let gen = GeneratorSource::new(12, 8, Distribution::Uniform, 2).unwrap();
    let path = std::env::temp_dir().join("srsvd_test_server_artifact_file.bin");
    let _src: FileSource = spill_to_file(&gen, &path, 4).unwrap();
    let mut req = JobRequest::new(file_input(path.to_str().unwrap(), None, None), 2);
    req.engine = EnginePreference::ArtifactOnly;
    let text = format!("{}", client.submit(&req).unwrap_err());
    assert!(text.contains("400"), "{text}");
    assert!(text.contains("streamed"), "{text}");

    // The adaptive stop criterion is native-only too.
    let mut req = JobRequest::new(dense_input(&x), 2);
    req.engine = EnginePreference::ArtifactOnly;
    req.config = req.config.with_tolerance(1e-3, 8);
    let text = format!("{}", client.submit(&req).unwrap_err());
    assert!(text.contains("400"), "{text}");
    assert!(text.contains("pve_tol"), "{text}");

    // The service is unharmed: the same jobs run fine on the native
    // engine, and the adaptive one reports its sweep usage.
    let mut req = JobRequest::new(dense_input(&x), 2);
    req.engine = EnginePreference::Native;
    req.config = req.config.with_tolerance(1e-3, 8);
    let wire = client.submit_wait(&req).unwrap();
    let out = wire.outcome.expect("adaptive native job failed");
    let sweeps = out.sweeps_used.expect("result must carry sweeps_used");
    assert!((1..=8).contains(&(sweeps as usize)), "sweeps {sweeps}");
    let pve = out.achieved_pve.expect("adaptive result must carry achieved_pve");
    assert!(pve > 0.0 && pve <= 1.0, "pve {pve}");

    let _ = std::fs::remove_file(&path);
    server.shutdown();
}

#[test]
fn queued_jobs_are_claimed_by_blocking_get() {
    let (_coord, server) = start_service(1, 16, 2);
    let mut client = client_for(&server);

    // A slow job so the zero-timeout poll sees it still running.
    let mut slow = JobRequest::new(
        generator_input(300, 500, Distribution::Uniform, 4, None, None),
        16,
    );
    slow.config = slow.config.with_fixed_power(2);
    let SubmitOutcome::Queued(id) = client.submit(&slow).unwrap() else {
        panic!("wait=false submit must queue");
    };
    // Zero-second poll: almost certainly still running -> 202.
    let mut polls = 0;
    loop {
        match client.wait_timeout(id, 0.0).unwrap() {
            WaitOutcome::Running => {
                polls += 1;
                assert!(polls < 10_000, "job never finished");
            }
            WaitOutcome::Done(r) => {
                r.outcome.expect("job failed");
                break;
            }
        }
    }
    // The id is forgotten once claimed.
    let err = client.wait(id).unwrap_err();
    assert!(format!("{err}").contains("404"), "{err}");
    // Unknown ids are 404 too.
    let err = client.wait(424242).unwrap_err();
    assert!(format!("{err}").contains("404"), "{err}");
    server.shutdown();
}

#[test]
fn graceful_shutdown_drains_in_flight_requests() {
    let (coord, server) = start_service(1, 16, 2);
    let addr = server.local_addr().to_string();

    // A deliberately slow job submitted with wait=true from another
    // thread; shutdown must let its response finish.
    let handle = std::thread::spawn(move || {
        let mut client = Client::connect(&addr).unwrap();
        let mut req = JobRequest::new(
            generator_input(500, 600, Distribution::Uniform, 8, None, None),
            20,
        );
        req.config = req.config.with_fixed_power(3);
        req.engine = EnginePreference::Native;
        client.submit_wait(&req)
    });

    // Wait until the request has actually been accepted (no blind
    // sleep: CI machines can be slow), then shut down mid-flight.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while coord.metrics().submitted == 0 {
        assert!(std::time::Instant::now() < deadline, "request never arrived");
        std::thread::sleep(Duration::from_millis(5));
    }
    let addr = server.local_addr();
    server.shutdown();

    // The in-flight request completed with a full response…
    let wire = handle.join().unwrap().expect("in-flight request was dropped");
    assert!(wire.outcome.is_ok());
    // …and the listener is really gone.
    assert!(Client::connect(&addr.to_string()).is_err());
}

#[test]
fn metrics_endpoint_reports_service_counters() {
    let (coord, server) = start_service(2, 16, 2);
    let mut client = client_for(&server);
    let mut rng = Xoshiro256pp::seed_from_u64(6);
    let x = Dense::from_fn(12, 24, |_, _| rng.next_uniform());
    for _ in 0..2 {
        client
            .submit_wait(&JobRequest::new(dense_input(&x), 2))
            .unwrap()
            .outcome
            .unwrap();
    }
    let m = client.metrics().unwrap();
    assert_eq!(m.get("http_accepted").unwrap().as_usize().unwrap(), 2);
    assert_eq!(m.get("http_rejected").unwrap().as_usize().unwrap(), 0);
    assert!(m.get("completed").unwrap().as_usize().unwrap() >= 2);
    assert!(m.get("http_bytes_in").unwrap().as_usize().unwrap() > 0);
    assert!(m.get("http_bytes_out").unwrap().as_usize().unwrap() > 0);
    // The HTTP counters and the coordinator snapshot are one view.
    let snap = coord.metrics();
    assert_eq!(snap.http_accepted, 2);
    assert_eq!(snap.queue_depth, 0);
    assert_eq!(snap.in_flight, 0);
    server.shutdown();
}
